"""Paper App. B Q2: adaptive step-size solvers waste NFE on rejections at
small budgets; fixed-grid DEIS dominates. Sweep tolerances on the adaptive
rhoRK23 and compare error-at-NFE against tAB-DEIS on the same trained model."""
from repro.core.adaptive import AdaptiveRK23

from .common import SDE, trained_problem, rmse_to_ref, solve


def run(quick: bool = False):
    _, eps, xT, ref = trained_problem()
    rows = []
    tols = [3e-1, 1e-1] if quick else [1.0, 3e-1, 1e-1, 3e-2, 1e-2]
    for tol in tols:
        solver = AdaptiveRK23(SDE, rtol=tol, atol=tol)
        res = solver.solve(eps, xT)
        rows.append({"table": "appB_Q2_adaptive", "solver": "rhoRK23_adaptive",
                     "tol": tol, "NFE": res.nfe,
                     "rejected_steps": res.n_rejected,
                     "wasted_nfe": 3 * res.n_rejected,
                     "rmse_to_ref": round(rmse_to_ref(res.x0, ref), 6)})
    for n in ([10, 20] if quick else [5, 10, 15, 20, 30]):
        x, nfe = solve(eps, xT, "tab3", n, "quadratic")
        rows.append({"table": "appB_Q2_adaptive", "solver": "tAB3_fixed",
                     "tol": None, "NFE": nfe, "rejected_steps": 0,
                     "wasted_nfe": 0,
                     "rmse_to_ref": round(rmse_to_ref(x, ref), 6)})
    return rows
