"""Matrix-coefficient DEIS on CLD (paper Sec. 2 generality claim): order-r
matrix-AB convergence against a fine-grid reference on exactly-scored CLD."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.matrix_sde import CLD, CLDGaussianOracle, cld_reference, cld_sample


def run(quick: bool = False):
    cld = CLD()
    orc = CLDGaussianOracle(cld, mean=1.0, var=0.25)
    eps = orc.eps_fn()
    m_t, s_t = orc._moments(1.0)
    z_T = jnp.asarray(m_t) + jax.random.normal(jax.random.PRNGKey(0), (128, 2)) \
        @ jnp.asarray(np.linalg.cholesky(s_t).T)
    ref = cld_reference(cld, eps, z_T, 800 if quick else 3000)
    rows = []
    for order in range(3):
        errs = {}
        for n in ([8, 16] if quick else [8, 16, 32]):
            ts = np.linspace(cld.T, cld.t0, n + 1)
            z0 = cld_sample(cld, ts, order, eps, z_T)
            errs[n] = float(jnp.sqrt(jnp.mean((z0 - ref) ** 2)))
        ns = sorted(errs)
        rate = float(np.log2(errs[ns[-2]] / errs[ns[-1]]))
        rows.append({"table": "cld_matrix_deis", "order": order,
                     **{f"rmse_N{n}": round(e, 6) for n, e in errs.items()},
                     "observed_rate": round(rate, 2)})
    return rows
