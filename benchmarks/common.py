"""Shared benchmark infrastructure: problems, metrics, CSV emission."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import VPSDE, VESDE, get_timesteps, make_plan, sample
from repro.diffusion.analytic import GMMData, default_gmm
from repro.diffusion.score_net import train_score_net, TrainedScoreModel

SDE = VPSDE()


@functools.lru_cache(maxsize=None)
def gmm_problem(d: int = 2):
    """Analytic-score GMM problem: (gmm, eps_fn, x_T, reference x_0)."""
    gmm = default_gmm(SDE, d=d)
    eps = gmm.eps_fn()
    x_T = jax.random.normal(jax.random.PRNGKey(0), (512, d)) * SDE.prior_std()
    ref = sample(make_plan("rho_rk4", SDE, get_timesteps(SDE, 500, "log_rho")),
                 eps, x_T)
    return gmm, eps, x_T, ref


@functools.lru_cache(maxsize=None)
def trained_problem(d: int = 2, steps: int = 1500):
    """Trained-score problem (real fitting error)."""
    gmm = default_gmm(SDE, d=d)
    model = train_score_net(SDE, lambda k, n: gmm.sample_data(k, n), d,
                            steps=steps, seed=0)
    eps = model.eps_fn()
    x_T = jax.random.normal(jax.random.PRNGKey(0), (512, d)) * SDE.prior_std()
    ref = sample(make_plan("rho_rk4", SDE, get_timesteps(SDE, 500, "log_rho")),
                 eps, x_T)
    return gmm, eps, x_T, ref


def rmse_to_ref(x, ref) -> float:
    """Discretization error Delta_p (paper Fig. 3a): same x_T, same model,
    distance to the (near-)exact ODE solution."""
    return float(jnp.sqrt(jnp.mean(jnp.square(x - ref))))


def sliced_w2(x, y, n_proj: int = 128, seed: int = 0) -> float:
    """Sliced 2-Wasserstein between sample sets (FID stand-in)."""
    key = jax.random.PRNGKey(seed)
    d = x.shape[-1]
    dirs = jax.random.normal(key, (n_proj, d))
    dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
    px = jnp.sort(x @ dirs.T, axis=0)
    py = jnp.sort(y @ dirs.T, axis=0)
    n = min(px.shape[0], py.shape[0])
    return float(jnp.sqrt(jnp.mean(jnp.square(px[:n] - py[:n]))))


def solve(eps, x_T, solver_name: str, nfe_grid: int, schedule: str = "quadratic",
          t0=None, key=None, **kw):
    plan = make_plan(solver_name, SDE,
                     get_timesteps(SDE, nfe_grid, schedule, t0=t0), **kw)
    return sample(plan, eps, x_T, key), plan.nfe


def timed(fn, *args, reps: int = 3):
    fn(*args)  # compile / warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6  # us


def emit(rows: list[dict], name: str):
    """Print rows and the required ``name,us_per_call,derived`` CSV line."""
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))


def write_bench(name: str, metrics: dict, out_path: str, *, quick: bool,
                extra_meta: dict | None = None) -> dict:
    """Write a ``BENCH_<name>.json`` perf-trajectory record.

    ``metrics`` maps dotted metric names to :func:`repro.obs.bench.metric`
    entries. The meta envelope stamps quick/full mode plus the backend and
    jax version, so ``repro.obs.bench compare`` can warn when two records
    are not commensurate. Returns the written record."""
    from repro.obs import bench

    meta = {"quick": bool(quick), "backend": jax.default_backend(),
            "jax": jax.__version__}
    if extra_meta:
        meta.update(extra_meta)
    rec = bench.record(name, metrics, meta)
    bench.write(out_path, rec)
    return rec
