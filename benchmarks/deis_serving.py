"""DEIS as a serving feature: streaming continuous-batching throughput.

Three measurements on a reduced backbone:

  * per-(solver, NFE) throughput -- serving capacity scales ~1/NFE, which is
    exactly why the paper's low-NFE quality matters operationally;
  * a mixed-traffic run: requests with different (solver, nfe, seq_len)
    admitted at different step boundaries, interleaved at NFE granularity by
    the streaming scheduler. The run asserts the compile cache stays at one
    trace per (plan.signature, batch, seq_len) -- no per-group recompilation
    -- and reports solve-only latency (compile time is tracked separately by
    the engine, so numbers are not poisoned by trace cost);
  * a mixed-PRIORITY ragged-NFE run under a throttled (EDF + aging)
    scheduler, once without and once with mid-flight group compaction. The
    ragged groups pad short plans to the bucket's longest grid, so without
    compaction every early-finished row burns one dead step per tick;
    compaction re-packs survivors into smaller cached batch buckets. The
    run reports p50/p99 request latency and ``wasted_row_steps``, asserts
    the wasted steps drop to zero under compaction, that both modes produce
    bitwise-identical per-request samples, and that the measured (warm)
    pass runs with ZERO recompilation -- compaction's shrunken batch sizes
    included, because they land in the same (signature, batch, seq_len)
    executor cache;
  * an EARLY-EXIT run: an engine under a RetirePolicy serves a mixed
    tab2/sndeis2/ddim workload; estimate-carrying rows retire once their
    embedded
    local-error estimate converges, and the run ratchets the (deterministic)
    early-exit count and saved NFEs at tol 0 -- the serving-side payoff of
    the embedded pairs;
  * a SHARDED mixed-traffic run on a forced 8-device host mesh (subprocess:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` must be set
    before jax imports). Ragged request waves -- including stochastic rows
    with distinct seeds and a 12-request burst whose 16-row group compacts
    to 8 mid-flight UNDER sharding -- run through the request-axis sharded
    engine and through the single-device engine; the child asserts the two
    are bitwise identical per request and that the sharded warm pass runs
    with ZERO recompilation (compaction's shrunken multiples land in the
    same mesh-keyed (signature, batch, seq_len, mesh) executor cache).
"""
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

import repro
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serving.engine import DiffusionServeEngine, Request


def _throughput_rows(eng, quick: bool):
    rows = []
    n_req = 4 if quick else 8
    for solver, nfe in ([("tab3", 5), ("tab3", 10), ("dpm3m", 10),
                         ("sndeis2", 10)] if quick else
                        [("ddim", 10), ("tab3", 5), ("tab3", 10), ("tab3", 20),
                         ("rho_heun", 5), ("dpm3m", 10), ("seeds2", 10),
                         ("scire2", 10), ("sndeis2", 10)]):
        reqs = [Request(uid=i, seq_len=32, nfe=nfe, solver=solver, seed=i)
                for i in range(n_req)]
        eng.serve(reqs)  # warm/compile
        t0 = time.perf_counter()
        res = eng.serve(reqs)
        dt = time.perf_counter() - t0
        assert all(r.compile_s == 0.0 for r in res), "warm serve recompiled"
        # report the TRUE evals spent (budgeted grids may round nfe down,
        # e.g. rho_heun at nfe=5 runs 4 evals) so ~1/NFE comparisons hold
        rows.append({"table": "deis_serving", "solver": solver,
                     "NFE": res[0].nfe, "requests": n_req,
                     "us_per_request": round(dt / n_req * 1e6, 1),
                     "seq_per_s": round(n_req / dt, 2)})
    return rows


def _mixed_traffic_row(eng, quick: bool):
    """Heterogeneous request waves admitted at different step boundaries."""
    waves = [
        [Request(uid=100 + i, seq_len=32, nfe=8, solver=s, seed=i)
         for i, s in enumerate(["ddim", "euler", "naive_ei", "ddim"])],
        [Request(uid=200 + i, seq_len=32, nfe=4, solver="tab2", seed=i)
         for i in range(2)],
        [Request(uid=300, seq_len=16, nfe=6, solver="em", seed=7),
         Request(uid=301, seq_len=16, nfe=6, solver="ddim_eta", eta=1.0,
                 seed=8)],
        # one request per next-gen family, all in one wave
        [Request(uid=500 + i, seq_len=32, nfe=6, solver=s, seed=20 + i)
         for i, s in enumerate(["dpm2m", "seeds1", "scire2", "sndeis2"])],
    ]
    if not quick:
        waves.append([Request(uid=400 + i, seq_len=32, nfe=8, solver="rho_heun",
                              seed=i) for i in range(2)])
    # warm every (signature, batch, seq_len) the waves will need
    for w in waves:
        eng.serve(list(w))
    executors_before = eng.num_executors

    results, steps = [], 0
    t0 = time.perf_counter()
    for w in waves:                      # admit each wave at a step boundary
        for r in w:
            eng.submit(r)
        results += eng.tick()            # interleaves with in-flight groups
        steps += 1
    while eng.busy:
        results += eng.tick()
        steps += 1
    dt = time.perf_counter() - t0

    n_req = sum(len(w) for w in waves)
    assert len(results) == n_req
    assert eng.num_executors == executors_before, (
        "mixed traffic caused recompilation beyond one trace per "
        "(plan.signature, batch, seq_len)")
    assert all(r.compile_s == 0.0 for r in results)
    return {"table": "deis_serving", "solver": "mixed", "NFE": "4-8",
            "requests": n_req, "scheduler_ticks": steps,
            "executors": eng.num_executors,
            "us_per_request": round(dt / n_req * 1e6, 1),
            "seq_per_s": round(n_req / dt, 2)}


def _ragged_priority_requests(quick: bool):
    """Mixed-priority, ragged-NFE workload: one ddim/euler family bucket per
    seq_len so admission builds ragged stacked groups. Deadlines/priorities
    are well separated so EDF ordering is deterministic across runs."""
    n_hi = 2 if quick else 4
    reqs = [Request(uid=i, seq_len=32, nfe=[4, 8, 12][i % 3],
                    solver=["ddim", "euler"][i % 2], seed=i, priority=0)
            for i in range(4 if quick else 8)]
    reqs += [Request(uid=100 + i, seq_len=32, nfe=4, solver="ddim",
                     seed=50 + i, priority=2, deadline_s=0.5)
             for i in range(n_hi)]
    return reqs


def _run_ragged(params, cfg, reqs, *, compaction: bool):
    """Two passes (cold compile, warm measure) of the ragged workload under a
    throttled EDF scheduler; returns (engine, warm results, latencies).

    Latency is END-TO-END per request (submit to Result emission), so it
    includes the queueing/skip delay the priority scheduler actually moves
    around -- ``Result.latency_s`` alone is solve-only and would hide it."""
    eng = DiffusionServeEngine(params, cfg, steps_per_tick=2, aging_ticks=4,
                               compaction=compaction, max_group=8)
    eng.serve(list(reqs))                 # cold: compile every bucket size
    eng.wasted_row_steps = 0
    eng.ticks = 0
    executors_before = eng.num_executors
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    results, e2e = [], []
    while eng.busy:
        for res in eng.tick():
            e2e.append(time.perf_counter() - t0)
            results.append(res)
    wall = time.perf_counter() - t0
    assert eng.num_executors == executors_before, (
        "warm ragged run recompiled: compaction bucket sizes must reuse the "
        "(signature, batch, seq_len) executor cache")
    assert all(r.compile_s == 0.0 for r in results)
    return eng, results, sorted(e2e), wall


def _ragged_priority_rows(params, cfg, quick: bool):
    reqs = _ragged_priority_requests(quick)
    rows, tokens = [], {}
    for compaction in (False, True):
        eng, results, lat, wall = _run_ragged(params, cfg, reqs,
                                              compaction=compaction)
        tokens[compaction] = {r.uid: r.tokens for r in results}
        p50 = lat[len(lat) // 2]
        p99 = lat[min(len(lat) - 1, int(len(lat) * 0.99))]
        rows.append({"table": "deis_serving",
                     "solver": "ragged_priority",
                     "compaction": compaction, "requests": len(reqs),
                     "scheduler_ticks": eng.ticks,
                     "wasted_row_steps": eng.wasted_row_steps,
                     "p50_ms": round(p50 * 1e3, 2),
                     "p99_ms": round(p99 * 1e3, 2),
                     "seq_per_s": round(len(reqs) / wall, 2)})
    # compaction must eliminate dead-row steps without changing any sample
    assert rows[1]["wasted_row_steps"] == 0 < rows[0]["wasted_row_steps"], (
        "compaction failed to reduce wasted row steps "
        f"({rows[0]['wasted_row_steps']} -> {rows[1]['wasted_row_steps']})")
    for uid in tokens[True]:
        np.testing.assert_array_equal(tokens[True][uid], tokens[False][uid])
    return rows


# ------------------------------------- continuous admission (joins) section
def _continuous_requests(quick: bool):
    """A staggered ragged-NFE stream in ONE ddim/euler family bucket: two
    requests arrive per tick, so by the time later waves land, earlier
    groups have retired rows -- exactly the boundary joins exploit."""
    n = 8 if quick else 16
    return [(i // 2, Request(uid=i, seq_len=32, nfe=[3, 6, 9][i % 3],
                             solver=["ddim", "euler"][i % 2], seed=i))
            for i in range(n)]


def _run_continuous(params, cfg, arrivals, *, continuous: bool):
    """Cold pass (compiles), then a warm measured pass of the staggered
    stream under a throttled scheduler. ``continuous`` enables
    join-at-compaction (+ compaction); off is the static-admission world
    where every wave forms its own group and dead rows ride along.

    Queue wait is per-request end-to-end time MINUS its solve latency
    (``Result.latency_s`` counts from the row's own admission), i.e. the
    time the scheduler left the request waiting -- pending, skipped, or
    riding unselected groups."""
    eng = DiffusionServeEngine(params, cfg, steps_per_tick=2, aging_ticks=4,
                               max_group=4, compaction=continuous,
                               join=continuous)

    arrival_tick = {r.uid: at for at, r in arrivals}

    def run():
        pending = sorted(arrivals, key=lambda a: a[0])
        i, t = 0, 0
        t0 = time.perf_counter()
        sub_t, results, e2e, wait_ticks = {}, [], {}, {}
        while i < len(pending) or eng.busy:
            while i < len(pending) and pending[i][0] <= t:
                sub_t[pending[i][1].uid] = time.perf_counter()
                eng.submit(pending[i][1])
                i += 1
            for res in eng.tick():
                e2e[res.uid] = time.perf_counter() - sub_t[res.uid]
                # scheduling delay in TICKS: completion tick minus arrival
                # tick minus the request's own step count (its floor). The
                # schedule is deterministic, so this metric is load- and
                # machine-independent -- what the mode comparison asserts
                # on (the wall-clock percentiles are reported, not
                # asserted: they flex with CPU contention).
                wait_ticks[res.uid] = (t - arrival_tick[res.uid] + 1
                                       - res.nfe)
                results.append(res)
            t += 1
        return results, e2e, wait_ticks, time.perf_counter() - t0

    run()                                   # cold: compile every bucket
    eng.wasted_row_steps = 0
    eng.ticks = 0
    eng.joined_requests = 0
    executors_before = eng.num_executors
    results, e2e, wait_ticks, wall = run()  # warm, measured
    assert eng.num_executors == executors_before, (
        "warm continuous-admission run recompiled: joined/compacted batches "
        "must reuse the (signature, batch, seq_len) executor cache")
    assert all(r.compile_s == 0.0 for r in results)
    waits = sorted(max(0.0, e2e[r.uid] - r.latency_s) for r in results)
    mean_wait_ticks = sum(wait_ticks.values()) / len(wait_ticks)
    return eng, results, waits, mean_wait_ticks, wall


def _continuous_admission_rows(params, cfg, quick: bool):
    arrivals = _continuous_requests(quick)
    rows, tokens, mean_wait = [], {}, {}
    for continuous in (False, True):
        eng, results, waits, wait_ticks, wall = _run_continuous(
            params, cfg, arrivals, continuous=continuous)
        tokens[continuous] = {r.uid: r.tokens for r in results}
        mean_wait[continuous] = wait_ticks
        rows.append({"table": "deis_serving",
                     "solver": "continuous_admission",
                     "joins": continuous, "requests": len(arrivals),
                     "scheduler_ticks": eng.ticks,
                     "joined_requests": eng.joined_requests,
                     "wasted_row_steps": eng.wasted_row_steps,
                     "mean_wait_ticks": round(wait_ticks, 2),
                     "mean_wait_ms": round(
                         sum(waits) / len(waits) * 1e3, 2),
                     "p50_wait_ms": round(waits[len(waits) // 2] * 1e3, 2),
                     "p99_wait_ms": round(
                         waits[min(len(waits) - 1,
                                   int(len(waits) * 0.99))] * 1e3, 2),
                     "warm_recompiles": 0,
                     "seq_per_s": round(len(arrivals) / wall, 2)})
    # continuous admission must cut both the (deterministic, tick-counted)
    # queue wait and the dead-row steps ...
    assert mean_wait[True] < mean_wait[False], (
        f"joins did not reduce mean scheduling delay "
        f"({mean_wait[False]:.2f} -> {mean_wait[True]:.2f} ticks)")
    assert rows[1]["wasted_row_steps"] == 0 < rows[0]["wasted_row_steps"]
    assert rows[1]["joined_requests"] > 0
    # ... without changing a single sample
    for uid in tokens[True]:
        np.testing.assert_array_equal(tokens[True][uid], tokens[False][uid])
    return rows


# -------------------------------------------------- early-exit (saved NFEs)
def _early_exit_rows(params, cfg, quick: bool):
    """Adaptive early-exit serving: an engine with a RetirePolicy retires
    rows whose embedded local-error estimate has converged, spending fewer
    NFEs than the request budgeted. The workload mixes estimate-carrying
    tab2 and sndeis2 (score-normalized pair, ``E * nu``) requests with
    pair-less ddim ones (which must always run their full budget).
    Early-exit counts and saved NFEs are deterministic
    functions of the seeded workload and the policy (the retire decision is
    per-row and timing-independent), so they ratchet at tol 0."""
    from repro.core.adaptive import RetirePolicy

    n = 6 if quick else 12
    reqs = [Request(uid=i, seq_len=32, nfe=[6, 9, 12][i % 3],
                    solver=("ddim" if i % 4 == 3 else
                            "sndeis2" if i % 4 == 1 else "tab2"), seed=i)
            for i in range(n)]
    eng = DiffusionServeEngine(params, cfg, steps_per_tick=2, max_group=4,
                               retire=RetirePolicy(tol=1.0, min_k=2))
    eng.serve(list(reqs))                  # cold: compile every bucket
    m = eng.metrics
    base_early = m.get("serve_early_exit_total").value
    base_saved = m.get("serve_saved_nfe_total").value
    executors_before = eng.num_executors
    t0 = time.perf_counter()
    results = eng.serve(list(reqs))        # warm, measured
    dt = time.perf_counter() - t0
    assert eng.num_executors == executors_before, (
        "warm early-exit run recompiled: estimate-carrying plans must reuse "
        "the (signature, batch, seq_len) executor cache")
    assert all(r.compile_s == 0.0 for r in results)

    by = {r.uid: r for r in results}
    budget = {q.uid: q.nfe for q in reqs}
    early = int(m.get("serve_early_exit_total").value - base_early)
    saved = int(m.get("serve_saved_nfe_total").value - base_saved)
    assert early == sum(r.early_exit for r in results) > 0
    assert saved == sum(budget[u] - by[u].nfe for u in by
                        if by[u].early_exit) > 0
    assert any(by[q.uid].early_exit for q in reqs if q.solver == "sndeis2"), (
        "no score-normalized (sndeis2) row early-exited under the policy")
    for q in reqs:                         # pair-less rows run their budget
        if q.solver == "ddim":
            assert not by[q.uid].early_exit and by[q.uid].nfe == q.nfe
    total = sum(budget.values())
    return [{"table": "deis_serving", "solver": "early_exit",
             "requests": len(reqs), "early_exits": early,
             "saved_nfe": saved, "budget_nfe": total,
             "nfe_saved_frac": round(saved / total, 3),
             "warm_recompiles": 0,
             "us_per_request": round(dt / len(reqs) * 1e6, 1),
             "seq_per_s": round(len(reqs) / dt, 2)}]


# ------------------------------------------------ sharded (8-device) section
# Runs in a child process because the forced host-device count only takes
# effect before jax is imported (this process already has 1 CPU device).
_SHARDED_CHILD = """
import json, time
import jax, numpy as np
assert jax.device_count() == 8, jax.device_count()
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serving.engine import DiffusionServeEngine, Request
from repro.launch.mesh import make_request_mesh

QUICK = %(quick)r
cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
params = T.init_params(cfg, jax.random.PRNGKey(0))

# mixed traffic: a ragged deterministic burst (compacts 16 -> 8 mid-flight
# under sharding), plus a stochastic wave with distinct per-request seeds
reqs = [Request(uid=i, seq_len=16, nfe=[4, 8][i %% 2], solver="ddim", seed=i)
        for i in range(6 if QUICK else 12)]
reqs += [Request(uid=100 + i, seq_len=16, nfe=4, solver="em", seed=50 + i)
         for i in range(2 if QUICK else 3)]

base = DiffusionServeEngine(params, cfg, max_group=16)
want = {r.uid: r.tokens for r in base.serve(list(reqs))}

eng = DiffusionServeEngine(params, cfg, max_group=16, mesh=make_request_mesh())
eng.serve(list(reqs))                       # cold: compile every mesh bucket
executors = eng.num_executors
t0 = time.perf_counter()
res = eng.serve(list(reqs))                 # warm, measured
dt = time.perf_counter() - t0
got = {r.uid: r.tokens for r in res}

assert eng.num_executors == executors, "sharded warm serve recompiled"
assert all(r.compile_s == 0.0 for r in res)
batches = sorted({k[1] for k in eng._compiled})
assert all(b %% 8 == 0 for b in batches), batches   # groups place evenly
assert want.keys() == got.keys()
for uid in want:                            # bitwise vs single-device path
    np.testing.assert_array_equal(got[uid], want[uid])
print("ROWS " + json.dumps([{
    "table": "deis_serving", "solver": "sharded_8dev",
    "requests": len(reqs), "devices": jax.device_count(),
    "executor_batches": "/".join(str(b) for b in batches),
    "bitwise_vs_1dev": True, "warm_recompiles": 0,
    "us_per_request": round(dt / len(reqs) * 1e6, 1),
    "seq_per_s": round(len(reqs) / dt, 2)}]))
"""


def _sharded_rows(quick: bool):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    # repro may be a namespace package (no __init__), so resolve via __path__
    pkg_root = os.path.dirname(list(repro.__path__)[0])
    env["PYTHONPATH"] = os.pathsep.join(
        [pkg_root] + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_CHILD % {"quick": quick}],
        capture_output=True, text=True, timeout=1800, env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded benchmark child failed:\n{out.stdout}\n{out.stderr}")
    line = [ln for ln in out.stdout.splitlines() if ln.startswith("ROWS ")][-1]
    return json.loads(line[len("ROWS "):])


def run(quick: bool = False):
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = DiffusionServeEngine(params, cfg)
    rows = _throughput_rows(eng, quick)
    rows.append(_mixed_traffic_row(eng, quick))
    rows += _ragged_priority_rows(params, cfg, quick)
    rows += _continuous_admission_rows(params, cfg, quick)
    rows += _early_exit_rows(params, cfg, quick)
    rows += _sharded_rows(quick)
    return rows


# ------------------------------------------------- BENCH_serving.json record
def bench_metrics(rows: list[dict]) -> dict:
    """Convert run() rows into a named metric series for ``obs.bench``.

    Scheduler metrics that are deterministic functions of the (seeded)
    workload and the scheduling policy -- wasted row steps, tick counts,
    join counts, tick-denominated queue waits, warm recompiles, executor
    traces -- ratchet at tol 0: ANY drift is a scheduling regression (or an
    intentional policy change, in which case the committed baseline is
    updated in the same PR). Wall-clock timings ride along as
    ``ratchet: false`` trajectory points; they flex with the host."""
    from repro.obs.bench import metric

    out = {}
    for r in rows:
        sol = r["solver"]
        if sol == "ragged_priority":
            pre = ("ragged_priority.compaction_on" if r["compaction"]
                   else "ragged_priority.compaction_off")
            out[f"{pre}.wasted_row_steps"] = metric(
                r["wasted_row_steps"], unit="steps", ratchet=True, tol=0.0)
            out[f"{pre}.scheduler_ticks"] = metric(
                r["scheduler_ticks"], unit="ticks", ratchet=True, tol=0.0)
            out[f"{pre}.p50_ms"] = metric(r["p50_ms"], unit="ms")
            out[f"{pre}.p99_ms"] = metric(r["p99_ms"], unit="ms")
        elif sol == "continuous_admission":
            pre = ("continuous_admission.joins_on" if r["joins"]
                   else "continuous_admission.joins_off")
            out[f"{pre}.wasted_row_steps"] = metric(
                r["wasted_row_steps"], unit="steps", ratchet=True, tol=0.0)
            out[f"{pre}.joined_requests"] = metric(
                r["joined_requests"], unit="requests", direction="higher",
                ratchet=True, tol=0.0)
            out[f"{pre}.mean_wait_ticks"] = metric(
                r["mean_wait_ticks"], unit="ticks", ratchet=True, tol=0.0)
            out[f"{pre}.warm_recompiles"] = metric(
                r["warm_recompiles"], unit="compiles", ratchet=True, tol=0.0)
            out[f"{pre}.mean_wait_ms"] = metric(r["mean_wait_ms"], unit="ms")
        elif sol == "early_exit":
            out["early_exit.early_exits"] = metric(
                r["early_exits"], unit="requests", direction="higher",
                ratchet=True, tol=0.0)
            out["early_exit.saved_nfe"] = metric(
                r["saved_nfe"], unit="evals", direction="higher",
                ratchet=True, tol=0.0)
            out["early_exit.warm_recompiles"] = metric(
                r["warm_recompiles"], unit="compiles", ratchet=True, tol=0.0)
            out["early_exit.nfe_saved_frac"] = metric(
                r["nfe_saved_frac"], unit="frac", direction="higher")
            out["early_exit.us_per_request"] = metric(
                r["us_per_request"], unit="us")
        elif sol == "mixed":
            out["mixed.executors"] = metric(
                r["executors"], unit="traces", ratchet=True, tol=0.0)
            out["mixed.us_per_request"] = metric(
                r["us_per_request"], unit="us")
        elif sol == "sharded_8dev":
            out["sharded_8dev.warm_recompiles"] = metric(
                r["warm_recompiles"], unit="compiles", ratchet=True, tol=0.0)
            out["sharded_8dev.us_per_request"] = metric(
                r["us_per_request"], unit="us")
        else:  # per-(solver, NFE) throughput rows
            pre = f"throughput.{sol}_nfe{r['NFE']}"
            out[f"{pre}.us_per_request"] = metric(
                r["us_per_request"], unit="us")
            out[f"{pre}.seq_per_s"] = metric(
                r["seq_per_s"], unit="seq/s", direction="higher")
    return out


def main(argv=None) -> int:
    import argparse

    from .common import write_bench

    ap = argparse.ArgumentParser(prog="benchmarks.deis_serving")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="where to write the bench record (default "
                         "BENCH_serving.json in the cwd)")
    args = ap.parse_args(argv)
    rows = run(quick=args.quick)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    write_bench("serving", bench_metrics(rows), args.out, quick=args.quick)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
