"""DEIS as a serving feature: streaming continuous-batching throughput.

Two measurements on a reduced backbone:

  * per-(solver, NFE) throughput -- serving capacity scales ~1/NFE, which is
    exactly why the paper's low-NFE quality matters operationally;
  * a mixed-traffic run: requests with different (solver, nfe, seq_len)
    admitted at different step boundaries, interleaved at NFE granularity by
    the streaming scheduler. The run asserts the compile cache stays at one
    trace per (plan.signature, batch, seq_len) -- no per-group recompilation
    -- and reports solve-only latency (compile time is tracked separately by
    the engine, so numbers are not poisoned by trace cost).
"""
import time

import jax

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serving.engine import DiffusionServeEngine, Request


def _throughput_rows(eng, quick: bool):
    rows = []
    n_req = 4 if quick else 8
    for solver, nfe in ([("tab3", 5), ("tab3", 10)] if quick else
                        [("ddim", 10), ("tab3", 5), ("tab3", 10), ("tab3", 20),
                         ("rho_heun", 5)]):
        reqs = [Request(uid=i, seq_len=32, nfe=nfe, solver=solver, seed=i)
                for i in range(n_req)]
        eng.serve(reqs)  # warm/compile
        t0 = time.perf_counter()
        res = eng.serve(reqs)
        dt = time.perf_counter() - t0
        assert all(r.compile_s == 0.0 for r in res), "warm serve recompiled"
        # report the TRUE evals spent (budgeted grids may round nfe down,
        # e.g. rho_heun at nfe=5 runs 4 evals) so ~1/NFE comparisons hold
        rows.append({"table": "deis_serving", "solver": solver,
                     "NFE": res[0].nfe, "requests": n_req,
                     "us_per_request": round(dt / n_req * 1e6, 1),
                     "seq_per_s": round(n_req / dt, 2)})
    return rows


def _mixed_traffic_row(eng, quick: bool):
    """Heterogeneous request waves admitted at different step boundaries."""
    waves = [
        [Request(uid=100 + i, seq_len=32, nfe=8, solver=s, seed=i)
         for i, s in enumerate(["ddim", "euler", "naive_ei", "ddim"])],
        [Request(uid=200 + i, seq_len=32, nfe=4, solver="tab2", seed=i)
         for i in range(2)],
        [Request(uid=300, seq_len=16, nfe=6, solver="em", seed=7),
         Request(uid=301, seq_len=16, nfe=6, solver="ddim_eta", eta=1.0,
                 seed=8)],
    ]
    if not quick:
        waves.append([Request(uid=400 + i, seq_len=32, nfe=8, solver="rho_heun",
                              seed=i) for i in range(2)])
    # warm every (signature, batch, seq_len) the waves will need
    for w in waves:
        eng.serve(list(w))
    executors_before = eng.num_executors

    results, steps = [], 0
    t0 = time.perf_counter()
    for w in waves:                      # admit each wave at a step boundary
        for r in w:
            eng.submit(r)
        results += eng.tick()            # interleaves with in-flight groups
        steps += 1
    while eng.busy:
        results += eng.tick()
        steps += 1
    dt = time.perf_counter() - t0

    n_req = sum(len(w) for w in waves)
    assert len(results) == n_req
    assert eng.num_executors == executors_before, (
        "mixed traffic caused recompilation beyond one trace per "
        "(plan.signature, batch, seq_len)")
    assert all(r.compile_s == 0.0 for r in results)
    return {"table": "deis_serving", "solver": "mixed", "NFE": "4-8",
            "requests": n_req, "scheduler_ticks": steps,
            "executors": eng.num_executors,
            "us_per_request": round(dt / n_req * 1e6, 1),
            "seq_per_s": round(n_req / dt, 2)}


def run(quick: bool = False):
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = DiffusionServeEngine(params, cfg)
    rows = _throughput_rows(eng, quick)
    rows.append(_mixed_traffic_row(eng, quick))
    return rows
