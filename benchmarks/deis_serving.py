"""DEIS as a serving feature: diffusion-LM sampling throughput vs NFE on a
reduced backbone -- serving capacity scales ~1/NFE, which is exactly why the
paper's low-NFE quality matters operationally."""
import time

import jax

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serving.engine import DiffusionServeEngine, Request


def run(quick: bool = False):
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = DiffusionServeEngine(params, cfg)
    rows = []
    n_req = 4 if quick else 8
    for solver, nfe in ([("tab3", 5), ("tab3", 10)] if quick else
                        [("ddim", 10), ("tab3", 5), ("tab3", 10), ("tab3", 20),
                         ("rho_heun", 5)]):
        reqs = [Request(uid=i, seq_len=32, nfe=nfe, solver=solver, seed=i)
                for i in range(n_req)]
        eng.serve(reqs)  # warm/compile
        t0 = time.perf_counter()
        res = eng.serve(reqs)
        dt = time.perf_counter() - t0
        rows.append({"table": "deis_serving", "solver": solver, "NFE": nfe,
                     "requests": n_req,
                     "us_per_request": round(dt / n_req * 1e6, 1),
                     "seq_per_s": round(n_req / dt, 2)})
    return rows
