"""Paper Fig. 3 reproduction: the Exponential Integrator is WORSE than Euler
under the score (s_theta) parameterization with frozen L_t, and better under
the eps parameterization -- on concentrated data (paper Fig. 2 toy)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import VPSDE, get_timesteps, make_plan, sample
from repro.diffusion.analytic import GaussianData

from .common import SDE, rmse_to_ref


def run(quick: bool = False):
    d = 8
    g = GaussianData(SDE, mean=np.full(d, 1.0), var=np.full(d, 1e-4))
    eps = g.eps_fn()
    xT = jax.random.normal(jax.random.PRNGKey(0), (256, d)) * SDE.prior_std()
    exact = g.exact_flow(xT, SDE.T, SDE.t0)
    rows = []
    for n in ([10, 20] if quick else [5, 10, 20, 50, 100]):
        row = {"table": "fig3", "N": n}
        for name, label in [("naive_ei", "EI_s_param"), ("euler", "Euler"),
                            ("ddim", "EI_eps_param")]:
            plan = make_plan(name, SDE, get_timesteps(SDE, n, "uniform"))
            row[label] = round(rmse_to_ref(sample(plan, eps, xT), exact), 6)
        row["claim_ok"] = bool(row["EI_s_param"] > row["Euler"] > row["EI_eps_param"])
        rows.append(row)
    return rows
