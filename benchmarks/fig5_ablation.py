"""Paper Fig. 5 / Tab. 9: ingredient ablation -- Euler -> +EI -> +eps ->
+polynomial extrapolation -> +optimized timestamps, on a TRAINED score model
(real fitting error, as in the paper)."""
from .common import gmm_problem, trained_problem, rmse_to_ref, solve


def run(quick: bool = False):
    _, eps, xT, ref = trained_problem()
    nfes = [10, 20] if quick else [5, 10, 20, 50]
    rows = []
    for n in nfes:
        variants = [
            ("euler", dict(solver_name="euler", schedule="uniform")),
            ("+EI(s_param)", dict(solver_name="naive_ei", schedule="uniform")),
            ("+eps(DDIM)", dict(solver_name="ddim", schedule="uniform")),
            ("+poly(tAB3)", dict(solver_name="tab3", schedule="uniform")),
            ("+opt_t(tAB3,quad)", dict(solver_name="tab3", schedule="quadratic")),
        ]
        row = {"table": "fig5_tab9", "NFE": n}
        for label, kw in variants:
            x, _ = solve(eps, xT, nfe_grid=n, **kw)
            row[label] = round(rmse_to_ref(x, ref), 6)
        row["full_stack_beats_euler"] = bool(row["+opt_t(tAB3,quad)"] < row["euler"])
        rows.append(row)
    return rows
