"""Kernel microbench: Pallas vs jnp reference -- correctness delta +
structural roofline terms (bytes/flops per call derived analytically).

Kernel calls pass ``interpret=None``, resolving through the per-kernel
capability table: compiled Mosaic/Triton timings on TPU/GPU, the
interpreter only on CPU (whose wall-time is NOT a hardware proxy and is
reported only as us_per_call for the harness contract).

``main`` writes a ``BENCH_kernels.json`` perf-trajectory record via
``repro.obs.bench``: the analytic roofline terms ratchet at tol 0 (they are
pure functions of the problem shapes -- drift means the kernel's data
movement or flop count changed), the kernel-vs-reference error ratchets with
a generous relative tolerance (catches real numerics regressions without
tripping on cross-version float noise), and backend-resolved wall time rides
along unratcheted."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import timed


def run(quick: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)

    # deis_step: memory-bound fused update
    m, d, r = (1024, 256, 3) if not quick else (256, 128, 2)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (m, d))
    hist = jax.random.normal(ks[1], (r, m, d))
    psi = jnp.float32(0.95)
    coeffs = jax.random.normal(ks[2], (r,), jnp.float32)
    out_k, us_k = timed(lambda: ops.deis_step(x, hist, psi, coeffs,
                                              interpret=None))
    out_r, us_r = timed(lambda: ref.deis_step_ref(x, hist, psi, coeffs))
    bytes_moved = 4 * (m * d * (r + 2))  # read x+hist, write out
    rows.append({"table": "kernels", "kernel": "deis_step",
                 "max_abs_err": float(np.abs(np.asarray(out_k - out_r)).max()),
                 "us_per_call_interp": round(us_k, 1),
                 "hbm_bytes_per_call": bytes_moved,
                 "tpu_roofline_us": round(bytes_moved / 819e9 * 1e6, 2)})

    # flash attention
    b, s, h, dd = (1, 256, 4, 64) if not quick else (1, 128, 2, 32)
    q = jax.random.normal(ks[0], (b, s, h, dd))
    k2 = jax.random.normal(ks[1], (b, s, h, dd))
    v = jax.random.normal(ks[2], (b, s, h, dd))
    out_k, us_k = timed(lambda: ops.flash_attention(q, k2, v, blk_q=64, blk_k=64,
                                                    interpret=None))
    out_r, _ = timed(lambda: ref.flash_attention_ref(q, k2, v))
    flops = 4.0 * b * h * s * s * dd
    rows.append({"table": "kernels", "kernel": "flash_attention",
                 "max_abs_err": float(np.abs(np.asarray(out_k - out_r)).max()),
                 "us_per_call_interp": round(us_k, 1),
                 "flops_per_call": flops,
                 "tpu_roofline_us": round(flops / 197e12 * 1e6, 3)})

    # ssd_scan
    b, s, h, p, n = (1, 256, 4, 32, 32) if not quick else (1, 64, 2, 16, 16)
    x = jax.random.normal(ks[0], (b, s, h, p))
    a = jax.random.uniform(ks[1], (b, s, h), jnp.float32, 0.8, 0.999)
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    (y_k, st_k), us_k = timed(lambda: ops.ssd_scan(x, a, B, C, chunk=64,
                                                   interpret=None))
    (y_r, st_r), _ = timed(lambda: ref.ssd_scan_ref(x, a, B, C))
    chunk = 64
    flops = 2.0 * b * h * (s / chunk) * (chunk * chunk * n + chunk * chunk * p
                                         + 2 * chunk * p * n)
    rows.append({"table": "kernels", "kernel": "ssd_scan",
                 "max_abs_err": float(np.abs(np.asarray(y_k - y_r)).max()),
                 "us_per_call_interp": round(us_k, 1),
                 "flops_per_call": flops,
                 "tpu_roofline_us": round(flops / 197e12 * 1e6, 3)})
    return rows


# ------------------------------------------------- BENCH_kernels.json record
def bench_metrics(rows: list[dict]) -> dict:
    """Convert run() rows into a named metric series for ``obs.bench``."""
    from repro.obs.bench import metric

    out = {}
    for r in rows:
        pre = r["kernel"]
        out[f"{pre}.max_abs_err"] = metric(
            r["max_abs_err"], unit="abs", ratchet=True, tol=0.5)
        out[f"{pre}.tpu_roofline_us"] = metric(
            r["tpu_roofline_us"], unit="us", ratchet=True, tol=0.0)
        if "hbm_bytes_per_call" in r:
            out[f"{pre}.hbm_bytes_per_call"] = metric(
                r["hbm_bytes_per_call"], unit="bytes", ratchet=True, tol=0.0)
        if "flops_per_call" in r:
            out[f"{pre}.flops_per_call"] = metric(
                r["flops_per_call"], unit="flops", ratchet=True, tol=0.0)
        out[f"{pre}.us_per_call_interp"] = metric(
            r["us_per_call_interp"], unit="us")
    return out


def main(argv=None) -> int:
    import argparse

    from .common import write_bench

    ap = argparse.ArgumentParser(prog="benchmarks.kernel_bench")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_kernels.json",
                    help="where to write the bench record (default "
                         "BENCH_kernels.json in the cwd)")
    args = ap.parse_args(argv)
    rows = run(quick=args.quick)
    for r in rows:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    write_bench("kernels", bench_metrics(rows), args.out, quick=args.quick)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
