"""Paper App. B Q1: DEIS accelerates likelihood evaluation -- rhoRK (Kutta3)
NLL converges in ~36 NFE vs RK45's ~130+. Here: NLL estimated via the
transformed PF-ODE on the analytic GMM, compared to the GMM's EXACT NLL."""
import jax
import numpy as np

from repro.core.likelihood import nll_bits_per_dim

from .common import SDE, gmm_problem


def run(quick: bool = False):
    gmm, eps, _, _ = gmm_problem()
    x0 = gmm.sample_data(jax.random.PRNGKey(11), 32 if quick else 64)
    exact_nll = float(-gmm.log_prob(x0).mean() / x0.shape[-1] / np.log(2.0))
    rows = []
    for method, stages in [("kutta3", 3), ("rk4", 4), ("heun", 2)]:
        for n in ([4, 12] if quick else [4, 8, 12, 24, 48]):
            est = float(nll_bits_per_dim(SDE, eps, x0, n_steps=n,
                                         method=method).mean())
            rows.append({"table": "nll_appB", "method": method,
                         "NFE": n * stages, "bits_per_dim": round(est, 4),
                         "exact_bits_per_dim": round(exact_nll, 4),
                         "abs_err": round(abs(est - exact_nll), 5)})
    return rows
