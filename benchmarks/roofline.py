"""Roofline report: reads dry-run JSONL results (produced by
``python -m repro.launch.dryrun --all --out results/dryrun*.jsonl``) and emits
the per-(arch x shape x mesh) three-term table for EXPERIMENTS.md §Roofline.
Does not compile anything itself (the dry-run owns the 512-device namespace)."""
import glob
import json
import os


def _latest_results():
    cands = sorted(glob.glob("results/dryrun*.jsonl"), key=os.path.getmtime)
    if not cands:
        return None
    # prefer the extrapolated-cost sweep if present
    for c in reversed(cands):
        rows = [json.loads(l) for l in open(c)]
        if any(r.get("cost_extrapolated") for r in rows):
            return rows
    return [json.loads(l) for l in open(cands[-1])]


def run(quick: bool = False):
    rows_in = _latest_results()
    if rows_in is None:
        return [{"table": "roofline", "note": "no results/dryrun*.jsonl found; "
                 "run python -m repro.launch.dryrun --all --out results/dryrun.jsonl"}]
    out = []
    seen = set()
    for r in rows_in:
        key = (r["arch"], r["shape"], r.get("mesh"))
        if key in seen:
            continue
        seen.add(key)
        if r["status"] != "ok":
            out.append({"table": "roofline", "arch": r["arch"],
                        "shape": r["shape"], "mesh": r.get("mesh", "?"),
                        "status": r["status"]})
            continue
        t = r["roofline"]
        out.append({
            "table": "roofline", "arch": r["arch"], "shape": r["shape"],
            "mesh": r["mesh"],
            "compute_s": round(t["compute_s"], 6) if t["compute_s"] else None,
            "memory_s": round(t["memory_s"], 6) if t["memory_s"] else None,
            "collective_s": round(t["collective_s"], 6),
            "bottleneck": r["bottleneck"],
            "useful_flops_ratio": (round(r["useful_flops_ratio"], 3)
                                   if r.get("useful_flops_ratio") else None),
        })
    return out
