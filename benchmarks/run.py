"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME[,NAME..]]

Each module's run(quick) returns a list of dict rows; rows are printed as
``k=v`` CSV. A final ``name,us_per_call,derived`` summary line per table is
emitted for the harness contract.
"""
import argparse
import importlib
import time
import traceback

TABLES = [
    "fig3_parameterization",   # Fig. 3 (ingredients 1-2)
    "fig5_ablation",           # Fig. 5 / Tab. 9
    "table2_solvers",          # Tab. 2
    "table3_dpm",              # App. B Q5 / Tab. 3 (DPM-Solver comparison)
    "table4_ipndm",            # Tabs. 4-5
    "table6_schedules",        # Tabs. 6-8
    "table15_vesde",           # Tab. 15
    "cld_matrix",              # Sec. 2 matrix-coefficient generality (CLD)
    "nll_bench",               # App. B Q1
    "adaptive_bench",          # App. B Q2 (adaptive-step rejection waste)
    "deis_serving",            # serving integration
    "kernel_bench",            # Pallas kernels
    "roofline",                # §Roofline (reads dry-run output)
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = args.only.split(",") if args.only else TABLES

    summary = []
    failed = []
    for name in names:
        print(f"\n===== {name} =====")
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.perf_counter()
        try:
            rows = mod.run(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
            summary.append((name, -1.0, f"ERROR:{type(e).__name__}"))
            continue
        dt = time.perf_counter() - t0
        for r in rows:
            print(",".join(f"{k}={v}" for k, v in r.items()))
        us = dt / max(1, len(rows)) * 1e6
        summary.append((name, us, f"rows={len(rows)}"))

    print("\nname,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
