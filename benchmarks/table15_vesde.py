"""Paper Tab. 15: DEIS on VESDE -- acceleration transfers to the VE SDE
(coefficients via the same engine, no VP-specific closed forms)."""
import jax
import numpy as np

from repro.core import VESDE, get_timesteps, make_plan, sample
from repro.diffusion.analytic import default_gmm

from .common import rmse_to_ref


def run(quick: bool = False):
    sde = VESDE(sigma_max=25.0)
    gmm = default_gmm(sde, d=2)
    eps = gmm.eps_fn()
    xT = jax.random.normal(jax.random.PRNGKey(0), (512, 2)) * sde.prior_std()
    ref = sample(make_plan("rho_rk4", sde, get_timesteps(sde, 400, "log_rho")),
                 eps, xT)
    rows = []
    for n in ([10, 20] if quick else [5, 10, 20, 50]):
        row = {"table": "table15_vesde", "NFE": n}
        for r in range(4):
            name = "ddim" if r == 0 else f"tab{r}"
            plan = make_plan(name, sde, get_timesteps(sde, n, "log_rho"))
            row[f"tAB{r}"] = round(rmse_to_ref(sample(plan, eps, xT), ref), 6)
        rows.append(row)
    return rows
