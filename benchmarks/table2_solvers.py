"""Paper Tab. 2: all DEIS variants x NFE on a trained VPSDE model.
FID is replaced by RMSE-to-reference (discretization error) + sliced-W2 to the
data distribution (sample quality)."""
from .common import trained_problem, rmse_to_ref, sliced_w2, solve
import jax

SOLVERS = ["ddim", "rho_heun", "rho_kutta3", "rho_rk4",
           "rhoab1", "rhoab2", "rhoab3", "tab1", "tab2", "tab3",
           # next-gen families (kernel-agnostic: same ab/rk executors)
           "dpm2m", "dpm3m", "scire2", "scire3", "sndeis2", "sndeis3"]


def run(quick: bool = False):
    gmm, eps, xT, ref = trained_problem()
    data = gmm.sample_data(jax.random.PRNGKey(7), 512)
    rows = []
    for n in ([10, 20] if quick else [5, 10, 15, 20, 50]):
        for name in SOLVERS:
            x, nfe = solve(eps, xT, name, n, "quadratic")
            rows.append({"table": "table2", "grid_N": n, "solver": name,
                         "NFE": nfe,
                         "rmse_to_ref": round(rmse_to_ref(x, ref), 6),
                         "sliced_w2": round(sliced_w2(x, data), 6)})
    return rows
