"""Paper App. B Q5 / Tab. 3: DPM-Solver-2 (lambda-midpoint) vs rhoMid
(rho-midpoint DEIS) vs tAB-DEIS. Paper finding: DPM-Solver better at very low
NFE, differences shrink quickly; multistep tAB best at small budgets."""
from .common import trained_problem, rmse_to_ref, solve


def run(quick: bool = False):
    _, eps, xT, ref = trained_problem()
    rows = []
    for n in ([10, 20] if quick else [6, 10, 14, 20, 30, 50]):
        row = {"table": "table3_dpm", "grid_N": n}
        for name, label in [("dpm2", "DPM-Solver2"), ("rho_midpoint", "rhoMid"),
                            ("tab2", "tAB2"), ("tab3", "tAB3")]:
            x, nfe = solve(eps, xT, name, n, "log_rho")
            row[label] = round(rmse_to_ref(x, ref), 6)
            row[f"{label}_nfe"] = nfe
        rows.append(row)
    return rows
