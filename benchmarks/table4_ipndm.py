"""Paper Tabs. 4-5: PNDM vs iPNDM vs DDIM vs tAB-DEIS.
Key claims: iPNDM avoids PNDM's expensive RK warmup; tAB-DEIS beats both."""
from .common import trained_problem, rmse_to_ref, solve


def run(quick: bool = False):
    _, eps, xT, ref = trained_problem()
    rows = []
    for n in ([10, 20] if quick else [5, 10, 20, 50]):
        row = {"table": "table4_5", "grid_N": n}
        for name in ["ddim", "ipndm1", "ipndm2", "ipndm3", "tab1", "tab2", "tab3"]:
            x, nfe = solve(eps, xT, name, n, "quadratic")
            row[name] = round(rmse_to_ref(x, ref), 6)
            row[f"{name}_nfe"] = nfe
        if n >= 10:
            x, nfe = solve(eps, xT, "pndm", n, "quadratic")
            row["pndm"] = round(rmse_to_ref(x, ref), 6)
            row["pndm_nfe"] = nfe  # = n + 9 (RK warmup cost, paper App. H.1)
        rows.append(row)
    return rows
