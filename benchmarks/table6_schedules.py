"""Paper Tabs. 6-8: t0 x time-scheduling study (Eqs. 42-44)."""
from repro.core import get_timesteps, make_plan, sample

from .common import SDE, trained_problem, rmse_to_ref


def run(quick: bool = False):
    _, eps, xT, ref = trained_problem()
    rows = []
    schedules = [("power_t", dict(kappa=1.0)), ("power_t", dict(kappa=2.0)),
                 ("power_t", dict(kappa=3.0)), ("log_rho", {}),
                 ("power_rho", dict(kappa=7.0))]
    t0s = [1e-3, 1e-4]
    solvers = ["ddim", "tab2", "rhoab2", "rho_heun"] if quick else \
        ["ddim", "tab1", "tab2", "tab3", "rhoab2", "rho_heun", "rho_kutta3"]
    for n in ([10] if quick else [5, 10, 20]):
        for t0 in t0s:
            for sched, kw in schedules:
                ts = get_timesteps(SDE, n, sched, t0=t0, **kw)
                row = {"table": "table6_8", "NFE_grid": n, "t0": t0,
                       "schedule": f"{sched}{kw.get('kappa','')}"}
                for name in solvers:
                    plan = make_plan(name, SDE, ts)
                    row[name] = round(rmse_to_ref(sample(plan, eps, xT), ref), 6)
                rows.append(row)
    return rows
