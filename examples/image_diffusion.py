"""Pixel-space diffusion (the paper's original domain, scaled to CPU):
train an MLP score net on synthetic 8x8 'images' (two-class geometric
patterns + noise), then sweep every DEIS variant x NFE -- the Tab. 2
experience end to end on pixels.

    PYTHONPATH=src python examples/image_diffusion.py [--steps 2000]
"""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import VPSDE, get_timesteps, make_plan, sample
from repro.diffusion.score_net import train_score_net

H = W = 8
D = H * W


def make_images(key, n):
    """Synthetic 8x8 images: crosses and boxes with jitter (2 modes)."""
    k1, k2, k3 = jax.random.split(key, 3)
    cls = jax.random.bernoulli(k1, 0.5, (n,))
    yy, xx = jnp.meshgrid(jnp.arange(H), jnp.arange(W), indexing="ij")
    cross = ((yy == H // 2) | (xx == W // 2)).astype(jnp.float32)
    box = ((yy == 1) | (yy == H - 2) | (xx == 1) | (xx == W - 2)).astype(jnp.float32)
    base = jnp.where(cls[:, None, None], cross[None], box[None])
    imgs = base * 1.5 - 0.75 + 0.08 * jax.random.normal(k3, (n, H, W))
    return imgs.reshape(n, D)


def render(img):
    chars = " .:-=+*#%@"
    img = np.asarray(img).reshape(H, W)
    lo, hi = img.min(), img.max()
    scaled = ((img - lo) / (hi - lo + 1e-9) * (len(chars) - 1)).astype(int)
    return "\n".join("".join(chars[v] for v in row) for row in scaled)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=2000)
    args = ap.parse_args()

    sde = VPSDE()
    print(f"training {D}-dim pixel score net ({args.steps} steps) ...")
    model = train_score_net(sde, make_images, D, steps=args.steps,
                            hidden=256, depth=4,
                            log_every=max(1, args.steps // 4))
    eps = model.eps_fn()

    x_T = jax.random.normal(jax.random.PRNGKey(0), (256, D)) * sde.prior_std()
    ref = sample(make_plan("rho_rk4", sde, get_timesteps(sde, 300, "log_rho")),
                 eps, x_T)
    print(f"\n{'solver':10s}" + "".join(f"  NFE={n:<4d}" for n in (5, 10, 20)))
    best = {}
    for name in ("ddim", "tab2", "tab3", "ipndm3"):
        errs = []
        for n in (5, 10, 20):
            plan = make_plan(name, sde, get_timesteps(sde, n, "quadratic"))
            x = sample(plan, eps, x_T)
            errs.append(float(jnp.sqrt(jnp.mean((x - ref) ** 2))))
        best[name] = errs[1]
        print(f"{name:10s}" + "".join(f"  {e:8.4f}" for e in errs))

    p10 = make_plan("tab3", sde, get_timesteps(sde, 10, "quadratic"))
    samples = sample(p10, eps, x_T[:4])
    print("\ntAB3 @ 10 NFE samples:")
    for i in range(2):
        print(render(samples[i]), "\n")
    ok = best["tab3"] < best["ddim"]
    print("tAB3 beats DDIM at 10 NFE:", ok)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
