"""NLL evaluation via DEIS (paper App. B Q1): rhoRK-Kutta3 converges the
likelihood integral ~4x faster than a generic high-accuracy solve.

    PYTHONPATH=src python examples/likelihood_eval.py"""
import sys

import jax
import numpy as np

from repro.core import VPSDE
from repro.core.likelihood import nll_bits_per_dim
from repro.diffusion.analytic import default_gmm


def main():
    sde = VPSDE()
    gmm = default_gmm(sde, d=2)
    x0 = gmm.sample_data(jax.random.PRNGKey(0), 128)
    exact = float(-gmm.log_prob(x0).mean() / 2 / np.log(2.0))
    print(f"exact GMM NLL: {exact:.4f} bits/dim")
    print(f"{'method':8s} {'steps':>5s} {'NFE':>5s} {'bits/dim':>9s} {'err':>8s}")
    for method, stages in (("kutta3", 3), ("rk4", 4)):
        for n in (4, 8, 12, 24):
            est = float(nll_bits_per_dim(sde, gmm.eps_fn(), x0,
                                         n_steps=n, method=method).mean())
            print(f"{method:8s} {n:5d} {n * stages:5d} {est:9.4f} "
                  f"{abs(est - exact):8.5f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
