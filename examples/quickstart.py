"""Quickstart: train a small diffusion model on a 2D Gaussian mixture, then
sample with DEIS at 5/10/20 NFE and compare solvers.

    PYTHONPATH=src python examples/quickstart.py [--steps 1500]

What you should see: tAB3 at 10 NFE ~matches DDIM at 50 NFE (the paper's
headline result, Tab. 2, on this scale)."""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import VPSDE, get_timesteps, make_plan, sample
from repro.diffusion.analytic import default_gmm
from repro.diffusion.score_net import train_score_net


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1500)
    args = ap.parse_args()

    sde = VPSDE()
    gmm = default_gmm(sde, d=2)
    print("training score net on 8-mode GMM ...")
    model = train_score_net(sde, lambda k, n: gmm.sample_data(k, n), 2,
                            steps=args.steps, log_every=max(1, args.steps // 5))
    eps = model.eps_fn()

    x_T = jax.random.normal(jax.random.PRNGKey(0), (1024, 2)) * sde.prior_std()
    ref = sample(make_plan("rho_rk4", sde, get_timesteps(sde, 400, "log_rho")),
                 eps, x_T)

    print(f"\n{'solver':12s}" + "".join(f"  NFE={n:<4d}" for n in (5, 10, 20, 50)))
    for name in ("ddim", "tab1", "tab2", "tab3", "rho_heun", "ipndm3"):
        errs = []
        for n in (5, 10, 20, 50):
            plan = make_plan(name, sde, get_timesteps(sde, n, "quadratic"))
            x = sample(plan, eps, x_T)
            errs.append(float(jnp.sqrt(jnp.mean((x - ref) ** 2))))
        print(f"{name:12s}" + "".join(f"  {e:8.4f}" for e in errs))

    # headline check (paper Tab. 2: high-order DEIS >> DDIM at equal low NFE)
    p_deis = make_plan("tab3", sde, get_timesteps(sde, 10, "quadratic"))
    p_ddim = make_plan("ddim", sde, get_timesteps(sde, 10, "quadratic"))
    e_deis = float(jnp.sqrt(jnp.mean((sample(p_deis, eps, x_T) - ref) ** 2)))
    e_ddim = float(jnp.sqrt(jnp.mean((sample(p_ddim, eps, x_T) - ref) ** 2)))
    print(f"\n@10 NFE: tAB3 err={e_deis:.4f} vs DDIM err={e_ddim:.4f} -> "
          f"{'DEIS wins at equal NFE' if e_deis < e_ddim else 'check training'}")
    return 0 if e_deis < e_ddim else 1


if __name__ == "__main__":
    sys.exit(main())
