"""End-to-end serving driver: train a small diffusion-LM briefly, then serve
batched generation requests through the DEIS sampling service.

    PYTHONPATH=src python examples/serve_diffusion.py [--train-steps 60]

Demonstrates: config system -> data pipeline -> training loop -> checkpoint ->
serving engine with DEIS (the paper's technique) as the sampler, including the
~1/NFE throughput scaling that makes low-NFE solvers operationally valuable."""
import argparse
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import MarkovTextSource, make_batch
from repro.models import transformer as T
from repro.serving.engine import DiffusionServeEngine, Request
from repro.training import checkpoint as CKPT
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(cosine_schedule(3e-4, 10, args.train_steps))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    src = MarkovTextSource(cfg.vocab_size, seed=0)

    print(f"training reduced {cfg.name} diffusion-LM for {args.train_steps} steps ...")
    rng = jax.random.PRNGKey(1)
    for i in range(args.train_steps):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, src, i, args.batch, args.seq).items()}
        rng, sub = jax.random.split(rng)
        params, opt_state, metrics = step(params, opt_state, batch, sub)
        if i % max(1, args.train_steps // 5) == 0:
            print(f"  step {i}: loss={float(metrics['loss']):.4f} "
                  f"mse={float(metrics['mse']):.4f} ce={float(metrics['ce']):.4f}")

    with tempfile.TemporaryDirectory() as d:
        CKPT.save(d, args.train_steps, params, {"arch": cfg.name})
        params, meta = CKPT.restore(d, params)
        print(f"checkpoint round-trip OK (arch={meta['arch']})")

    eng = DiffusionServeEngine(params, cfg)
    print("\nserving batched requests:")
    for nfe, solver in [(5, "tab3"), (10, "tab3"), (20, "ddim")]:
        reqs = [Request(uid=i, seq_len=args.seq, nfe=nfe, solver=solver, seed=i)
                for i in range(8)]
        eng.serve(reqs)  # warm
        t0 = time.time()
        res = eng.serve(reqs)
        dt = time.time() - t0
        print(f"  {solver:5s} NFE={nfe:3d}: {len(res)} seqs in {dt:.2f}s "
              f"({len(res) / dt:.1f} seq/s), sample tokens: {res[0].tokens[:8]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
