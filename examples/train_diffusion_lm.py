"""Train a diffusion-LM on synthetic Markov text and watch DEIS sampling
quality improve with solver order.

    PYTHONPATH=src python examples/train_diffusion_lm.py --arch mamba2_2p7b

Works with ANY of the 10 assigned architectures (reduced variants on CPU) --
the paper's solver is architecture-agnostic."""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import VPSDE, get_timesteps, make_plan
from repro.data.pipeline import MarkovTextSource, make_batch
from repro.diffusion import lm as DLM
from repro.models import transformer as T
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.steps import make_train_step


def bigram_band_score(tokens, vocab, band=16):
    """Fraction of adjacent pairs consistent with the banded Markov source --
    a cheap 'is it learning the data distribution' metric for generations."""
    t = np.asarray(tokens)
    d = np.abs((t[:, 1:] - t[:, :-1]) % vocab)
    d = np.minimum(d, vocab - d)
    return float((d < band).mean())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().with_(objective="diffusion")
    print(f"arch={cfg.name} ({cfg.arch_type}), reduced: {cfg.n_layers}L "
          f"d={cfg.d_model} vocab={cfg.vocab_size}")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(cosine_schedule(3e-4, 10, args.steps))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    src = MarkovTextSource(cfg.vocab_size, seed=0)

    rng = jax.random.PRNGKey(1)
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, src, i, args.batch, args.seq).items()}
        rng, sub = jax.random.split(rng)
        params, opt_state, m = step(params, opt_state, batch, sub)
        if i % max(1, args.steps // 6) == 0:
            print(f"step {i:4d}: loss={float(m['loss']):.4f}")

    sde = VPSDE()
    data_score = bigram_band_score(src.batch(0, 64, args.seq), cfg.vocab_size)
    rand_score = bigram_band_score(
        np.random.randint(0, cfg.vocab_size, (64, args.seq)), cfg.vocab_size)
    print(f"\nbigram-band score: data={data_score:.3f} random={rand_score:.3f}")
    for solver, nfe in (("ddim", 10), ("tab2", 10), ("tab3", 10)):
        plan = make_plan(solver, sde, get_timesteps(sde, nfe, "quadratic"))
        kw = {}
        if cfg.arch_type == "vlm":
            kw["prefix"] = jnp.zeros((8, cfg.prefix_tokens, cfg.d_model))
        if cfg.arch_type == "encdec":
            kw["frames"] = jnp.zeros((8, cfg.encoder_seq, cfg.d_model))
        toks, _ = DLM.sample_tokens(params, cfg, plan, jax.random.PRNGKey(9),
                                    batch=8, seq_len=args.seq,
                                    prior_std=sde.prior_std(), **kw)
        print(f"{solver:6s}@{nfe}NFE: gen bigram-band score = "
              f"{bigram_band_score(toks, cfg.vocab_size):.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
