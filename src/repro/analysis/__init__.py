"""repro.analysis -- repo-specific static analysis for the DEIS stack.

Five AST-based checkers (stdlib ``ast``, no third-party deps) mechanize
the invariants the repo previously defended only by convention:

* **RL001** host-sync lint: no ``.item()`` / ``block_until_ready`` /
  ``np.asarray`` / scalar coercions / device-valued branches / ``print``
  inside the solver hot path (sampler, plan splice primitives, kernels,
  the engine tick path, the obs fast path).
* **RL002** recompile-hazard lint: every ``jax.jit`` call site -- jit
  inside loops, loop-variable closure capture, non-literal or missing
  ``static_argnames``, f-string / dict-order compile-cache keys.
* **RL003** serving lock discipline: the driver/engine/registry threading
  contract as an ownership table, enforced over method call graphs.
* **RL004** plan-leaf guard: coefficient keys built by ``plan_*`` builders
  must be classifiable by ``core/plan``'s role registries and covered by
  the sharding specs.
* **RL005** interpret-default guard: no jitted kernel signature may
  default ``interpret=True`` -- the literal that once shipped the Pallas
  interpreter to backends that could compile (default ``None``, resolve
  through ``repro.kernels.runtime.default_interpret``).

Run ``python -m repro.analysis src/`` (CI's lint job does, ratcheting the
per-rule counts via ``BENCH_static.json``). Suppress an intentional site
with ``# repro: allow[RULE] <one-line justification>``. See
docs/static_analysis.md for the full catalog.
"""
from .base import Checker, FileContext, Violation
from .cli import CHECKERS, RULES, Report, analyze, main, write_bench

__all__ = ["Checker", "FileContext", "Violation", "CHECKERS", "RULES",
           "Report", "analyze", "main", "write_bench"]
