"""Shared machinery for :mod:`repro.analysis` -- violations, suppression
comments, file contexts, and the checker plugin protocol.

Everything is stdlib-``ast`` based: checkers receive parsed
:class:`FileContext` objects (one per target file) and yield
:class:`Violation` records. Suppression is per-rule and per-line::

    x = arr.item()  # repro: allow[RL001] boundary read, solve already done

A matching ``# repro: allow[RULE]`` on the violation's line (or the line
directly above, for calls that span lines) marks it ``allowed``: it is
reported (and counted in the JSON/bench output) but does not fail the run.
File-level directives use the same comment namespace -- ``# repro: hot-path``
opts a whole file into the RL001 hot-path scope (used by the test fixtures).
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Iterable, Optional, Sequence

_ALLOW = re.compile(r"#\s*repro:\s*allow\[([A-Za-z0-9_,\s]+)\]")
_DIRECTIVE = re.compile(r"#\s*repro:\s*(hot-path)\b")


def _comments(src: str) -> list[tuple[int, str]]:
    """(line, text) of every real comment token -- so a docstring that merely
    *mentions* ``# repro: hot-path`` cannot trigger the directive."""
    out: list[tuple[int, str]] = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(src).readline):
            if tok.type == tokenize.COMMENT:
                out.append((tok.start[0], tok.string))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        pass
    return out


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: where, which rule, what went wrong."""
    rule: str
    path: str
    line: int
    col: int
    message: str
    allowed: bool = False

    def format(self) -> str:
        mark = "  [allowed]" if self.allowed else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}{mark}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class FileContext:
    """A parsed target file plus its suppression/directive comments."""

    def __init__(self, path: str, src: str, tree: Optional[ast.Module],
                 error: Optional[SyntaxError] = None):
        self.path = path
        self.src = src
        self.tree = tree
        self.error = error
        self.lines = src.splitlines()
        self.allows: dict[int, set[str]] = {}
        self.directives: set[str] = set()
        for lineno, text in _comments(src):
            m = _ALLOW.search(text)
            if m:
                self.allows[lineno] = {r.strip() for r in
                                       m.group(1).split(",") if r.strip()}
            d = _DIRECTIVE.search(text)
            if d:
                self.directives.add(d.group(1))

    @classmethod
    def from_path(cls, path: Path) -> "FileContext":
        src = path.read_text()
        label = str(path)
        try:
            tree = ast.parse(src, filename=label)
        except SyntaxError as e:
            return cls(label, src, None, error=e)
        return cls(label, src, tree)

    @property
    def posix(self) -> str:
        return self.path.replace("\\", "/")

    def allowed(self, rule: str, line: int) -> bool:
        """True when ``line`` carries a matching ``# repro: allow[rule]``
        comment, or one appears in the contiguous comment block directly
        above it (multi-line justifications are encouraged)."""
        if rule in self.allows.get(line, ()):
            return True
        ln = line - 1
        while 1 <= ln <= len(self.lines):
            if not self.lines[ln - 1].lstrip().startswith("#"):
                return False
            if rule in self.allows.get(ln, ()):
                return True
            ln -= 1
        return False


class Checker:
    """Plugin protocol: subclass, set ``rule``/``title``, implement
    :meth:`check` over the whole target set (cross-file rules like RL003/
    RL004 need every file at once; per-file rules just iterate)."""

    rule: str = "RL000"
    title: str = ""

    def check(self, ctxs: Sequence[FileContext]) -> Iterable[Violation]:
        raise NotImplementedError

    def violation(self, ctx: FileContext, node, message: str) -> Violation:
        line = node if isinstance(node, int) else node.lineno
        col = 0 if isinstance(node, int) else node.col_offset
        return Violation(self.rule, ctx.path, line, col, message,
                         allowed=ctx.allowed(self.rule, line))


# --------------------------------------------------------------- AST helpers
def dotted(node: ast.AST) -> Optional[str]:
    """``'jax.numpy.asarray'`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_aliases(tree: ast.Module) -> dict[str, str]:
    """Local name -> dotted module it binds (``jnp`` -> ``jax.numpy``)."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    out[a.asname] = a.name
                else:
                    out[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def resolve(name: Optional[str], aliases: dict[str, str]) -> Optional[str]:
    """Rewrite the first segment of a dotted name through the import map."""
    if name is None:
        return None
    head, _, rest = name.partition(".")
    head = aliases.get(head, head)
    return f"{head}.{rest}" if rest else head
