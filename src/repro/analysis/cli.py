"""Runner + CLI for the analysis pass: ``python -m repro.analysis [paths]``.

Exit status is 0 when every finding is suppressed with a justified
``# repro: allow[RULE]`` comment (or there are none), 1 when any live
violation remains, 2 on usage errors. ``--json`` emits machine output;
``--bench PATH`` records per-rule violation counts as a ``bench.v1``
record via :mod:`repro.obs.bench` (ratcheted at tol 0, direction lower, by
CI's lint job against ``benchmarks/baselines/BENCH_static.json``).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Optional, Sequence

from .base import Checker, FileContext, Violation
from .host_sync import HostSyncChecker
from .interpret_default import InterpretDefaultChecker
from .locks import LockDisciplineChecker
from .plan_leaves import PlanLeafChecker
from .recompile import RecompileChecker

CHECKERS: tuple[Checker, ...] = (HostSyncChecker(), RecompileChecker(),
                                 LockDisciplineChecker(), PlanLeafChecker(),
                                 InterpretDefaultChecker())
RULES = tuple(c.rule for c in CHECKERS)

_SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules"}


def collect_targets(paths: Sequence[str]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(
                f for f in path.rglob("*.py")
                if not _SKIP_DIRS & set(part.name for part in f.parents)))
        elif path.suffix == ".py":
            out.append(path)
        else:
            raise FileNotFoundError(f"not a .py file or directory: {p}")
    return out


class Report:
    """All findings of one run, plus the counts the CLI/bench emit."""

    def __init__(self, violations: list[Violation], files: int,
                 rules: Sequence[str] = RULES):
        self.violations = violations
        self.files = files
        self.rules = tuple(rules)

    @property
    def active(self) -> list[Violation]:
        return [v for v in self.violations if not v.allowed]

    @property
    def allowed(self) -> list[Violation]:
        return [v for v in self.violations if v.allowed]

    @property
    def exit_code(self) -> int:
        return 1 if self.active else 0

    def counts(self, allowed: bool = False) -> dict:
        pool = self.allowed if allowed else self.active
        out = {r: 0 for r in self.rules}
        for v in pool:
            out[v.rule] = out.get(v.rule, 0) + 1
        return out

    def human(self) -> str:
        lines = [v.format() for v in sorted(
            self.violations, key=lambda v: (v.path, v.line, v.col, v.rule))]
        act, alw = len(self.active), len(self.allowed)
        lines.append(f"{self.files} file(s) analyzed: {act} violation(s), "
                     f"{alw} allowed")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {"files": self.files,
                "violations": [v.to_dict() for v in self.violations],
                "counts": self.counts(),
                "allowed_counts": self.counts(allowed=True)}


def analyze(paths: Sequence[str],
            rules: Optional[Sequence[str]] = None) -> Report:
    """Run the checkers over ``paths`` (files or directories)."""
    targets = collect_targets(paths)
    ctxs = [FileContext.from_path(p) for p in targets]
    violations: list[Violation] = []
    for ctx in ctxs:
        if ctx.error is not None:
            violations.append(Violation(
                "RL000", ctx.path, ctx.error.lineno or 0, 0,
                f"syntax error: {ctx.error.msg}"))
    active = [c for c in CHECKERS if rules is None or c.rule in rules]
    for checker in active:
        violations.extend(checker.check(ctxs))
    return Report(violations, len(ctxs),
                  rules=[c.rule for c in active] or RULES)


def write_bench(report: Report, path: str, targets: Sequence[str]) -> None:
    from repro.obs import bench
    metrics = {}
    for rule, n in report.counts().items():
        metrics[f"static.{rule}.violations"] = bench.metric(
            n, unit="violations", direction="lower", ratchet=True, tol=0.0)
    for rule, n in report.counts(allowed=True).items():
        metrics[f"static.{rule}.allowed"] = bench.metric(
            n, unit="sites", direction="lower", ratchet=False)
    metrics["static.files"] = bench.metric(
        report.files, unit="files", direction="higher", ratchet=False)
    bench.write(path, bench.record(
        "static_analysis", metrics, meta={"targets": list(targets),
                                          "rules": list(report.rules)}))


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis "
                    "(RL001 host-sync, RL002 recompile-hazard, "
                    "RL003 lock-discipline, RL004 plan-leaf, "
                    "RL005 interpret-default)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories (default: src)")
    ap.add_argument("--rules", help="comma-separated rule subset "
                                    f"(of {', '.join(RULES)})")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--bench", metavar="PATH",
                    help="also write a bench.v1 record of per-rule counts")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for c in CHECKERS:
            print(f"{c.rule}  {c.title}")
        return 0
    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = set(rules) - set(RULES)
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
    try:
        report = analyze(args.paths or ["src"], rules=rules)
    except FileNotFoundError as e:
        print(str(e), file=sys.stderr)
        return 2
    if args.bench:
        write_bench(report, args.bench, args.paths or ["src"])
    print(json.dumps(report.to_json(), indent=2) if args.as_json
          else report.human())
    return report.exit_code
