"""Repo-specific policy for the analysis pass: which files are hot path
(RL001), and who owns which serving-stack attribute (RL003).

This is deliberately data, not code: adding a new hot module or a new
engine/driver attribute means editing a table here (and the checkers tell
you when you forgot -- RL003 fails on attributes missing from the ownership
table). Scope patterns are regexes matched against the END of the posix
path, so the tables work from any checkout root and on the test fixtures.
"""
from __future__ import annotations

import dataclasses


# ------------------------------------------------------------- RL001 scopes
# Sub-check groups. "sync" = explicit host syncs (.item(), block_until_ready,
# jax.device_get, np.asarray/np.array of device values, print); "coerce" =
# float()/int()/bool() of possibly-device values; "branch" = Python `if`/
# `while` on a traced array (implicit bool() sync + retrace hazard).
SYNC = "sync"
COERCE = "coerce"
BRANCH = "branch"
ALL_CHECKS = frozenset({SYNC, COERCE, BRANCH})
SYNC_ONLY = frozenset({SYNC})


@dataclasses.dataclass(frozen=True)
class HotScope:
    """One hot-path region: a path pattern plus what is in scope there.

    ``functions``: only these def names are hot (None = whole module).
    ``entry``: ``(Class, method)`` -- the hot region is every method of
    Class reachable from that entry through self-calls (used for the engine
    tick path, so scheduling helpers stay covered as they are added).
    """
    pattern: str
    checks: frozenset = ALL_CHECKS
    functions: tuple | None = None
    entry: tuple | None = None


# The solver executor (everything in it runs under jit per step), the plan
# splice primitives serving calls between steps, the kernels, the
# observability fast path (spans/metrics sit inside the tick), and the
# engine tick path itself.
HOT_SCOPES = (
    HotScope(r"core/sampler\.py$"),
    HotScope(r"core/plan\.py$", functions=(
        # splice primitives + signature/role helpers run per tick inside the
        # serving loop; plan_* builders are host-side float64 precompute by
        # contract and are RL004's concern instead.
        "astype", "stack_plans", "pad_plan", "take_rows", "join_rows",
        "inert_row", "_rowless_signature", "_leaf_role", "signature",
        "family", "n_steps", "batch", "history_len")),
    HotScope(r"kernels/[^/]+\.py$"),
    HotScope(r"obs/(trace|metrics)\.py$", checks=SYNC_ONLY),
    HotScope(r"serving/engine\.py$",
             entry=("DiffusionServeEngine", "tick")),
)

# jnp functions that return host scalars/metadata, not device arrays --
# fine inside an `if` test.
HOST_SAFE_JNP = frozenset({
    "ndim", "shape", "size", "dtype", "issubdtype", "isdtype",
    "result_type", "iscomplexobj", "isscalar"})


# ---------------------------------------------------------- RL003 ownership
@dataclasses.dataclass(frozen=True)
class Ownership:
    """Thread-ownership declaration for one serving-stack class.

    Buckets (fnmatch patterns over attribute names):
      ``config``    -- immutable after __init__; readable from any thread,
                       never reassigned outside __init__.
      ``scheduler`` -- scheduler-thread-only state; never touched from a
                       transport-reachable method.
      ``locked``    -- shared state; every access must sit inside
                       ``with self.<lock>:``  (except in __init__).
      ``atomic``    -- intrinsically thread-safe objects (queue.Queue,
                       threading.Event, metrics handles): any thread, no lock.

    ``transport_entries`` are the public thread-safe entry points; methods
    reachable from them (through self-calls and ``delegates``) inherit the
    transport context and must obey the scheduler-only restriction. ``"*"``
    means every method. ``scheduler_entries`` seed the scheduler context
    (the tick loop). ``delegates`` maps attribute -> class for cross-object
    call-graph edges (the driver holding the engine).
    """
    lock: str | None = None
    transport_entries: tuple = ()
    scheduler_entries: tuple = ()
    config: tuple = ()
    scheduler: tuple = ()
    locked: tuple = ()
    atomic: tuple = ()
    delegates: dict = dataclasses.field(default_factory=dict)


OWNERSHIP = {
    # The engine is single-threaded by contract: the driver's scheduler
    # thread owns it. Anything the driver's transport surface reads off it
    # must be a metrics handle (atomic) or carry an explicit allow.
    "DiffusionServeEngine": Ownership(
        scheduler_entries=("tick", "serve", "submit", "cancel", "reset",
                           "busy"),
        config=("cfg", "sde", "schedule", "max_group", "steps_per_tick",
                "aging_ticks", "compaction", "join", "seq_len_buckets",
                "mesh", "_mesh_key", "_data_size", "_chunk_cap", "params",
                "_params_exec", "enforce_deadlines", "retire", "metrics",
                "tracer", "fused"),
        scheduler=("_plans", "_compiled", "_pending", "_active", "_arrivals",
                   "_boundary_results"),
        atomic=("_m_*", "_g_*", "_h_*"),
    ),
    "ServeDriver": Ownership(
        lock="_lock",
        transport_entries=("submit", "submit_async", "cancel", "stats",
                           "start", "stop", "__enter__", "__exit__"),
        scheduler_entries=("_run",),
        config=("engine", "stream_decode", "idle_wait_s", "max_pending",
                "metrics"),
        locked=("_streams", "_thread"),
        atomic=("_inbox", "_stop", "_lock", "_m_*", "_h_*"),
        delegates={"engine": "DiffusionServeEngine"},
    ),
    # Registration is the only locked registry operation; the metric handles
    # themselves are single-writer lock-free by design.
    "MetricsRegistry": Ownership(
        lock="_lock",
        transport_entries=("*",),
        locked=("_metrics",),
        atomic=("_lock",),
    ),
}


# ---------------------------------------------------------- RL004 registries
# The coefficient-role registries in core/plan.py (PR 8's registration
# guard) that every plan_* coefficient key must be classifiable by, and the
# modifier set allowed to overlap the primary roles.
ROLE_REGISTRIES = ("_PER_STEP_COEFFS", "_PER_KNOT_COEFFS", "_STATIC_COEFFS")
MODIFIER_REGISTRIES = ("_TIME_LIKE",)
