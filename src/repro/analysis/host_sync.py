"""RL001 -- host-sync lint for the solver hot path.

DEIS's value proposition is that a 10-NFE solve is ~10 cheap device steps;
one stray host sync per step serializes the device queue and erases the
win. Inside the configured hot scopes (``config.HOT_SCOPES``, or any file
carrying a ``# repro: hot-path`` directive) this checker flags:

* ``.item()``, ``x.block_until_ready()`` / ``jax.block_until_ready``,
  ``jax.device_get`` -- explicit device->host syncs ("sync" group);
* ``np.asarray`` / ``np.array`` of a value that is not provably host-side
  already -- an implicit transfer ("sync");
* ``print(...)`` -- host I/O in the step loop ("sync");
* ``float()`` / ``int()`` / ``bool()`` of a possibly-device value -- each
  is an implicit blocking transfer ("coerce");
* ``if``/``while``/``assert`` tests built from jnp array expressions or
  ``.any()``/``.all()`` calls -- an implicit ``bool()`` sync and, under
  jit, a TracerBoolConversionError waiting to happen ("branch").

A lightweight per-function taint pass tracks names assigned from numpy /
math / time / ``jax.device_get`` results so host-side bookkeeping (the
engine coercing an already-fetched error vector, say) does not get flagged.
Deliberate boundary syncs carry ``# repro: allow[RL001]`` with a one-line
justification -- see docs/static_analysis.md.
"""
from __future__ import annotations

import ast
import re
from typing import Iterable, Optional, Sequence

from . import config
from .base import Checker, FileContext, Violation, dotted, import_aliases, resolve

_SYNC_ATTRS = {"block_until_ready": "blocks until the device queue drains",
               "device_get": "explicit device->host transfer",
               "item": "device->host scalar sync"}
_COERCIONS = {"float", "int", "bool"}
# call roots whose results are host-side values, for the taint pass
_HOST_ROOTS = ("numpy.", "math.", "time.", "jax.device_get")


class HostSyncChecker(Checker):
    rule = "RL001"
    title = "host-sync lint (hot-path modules must not sync or branch on device values)"

    def check(self, ctxs: Sequence[FileContext]) -> Iterable[Violation]:
        for ctx in ctxs:
            if ctx.tree is None:
                continue
            for region, checks in self._regions(ctx):
                yield from _Scan(self, ctx, checks).run(region)

    # ------------------------------------------------------------- scoping
    def _regions(self, ctx: FileContext):
        """Yield (ast node, enabled checks) pairs for the hot regions of
        this file; empty when the file is not hot path."""
        if "hot-path" in ctx.directives:
            yield ctx.tree, config.ALL_CHECKS
            return
        for scope in config.HOT_SCOPES:
            if not re.search(scope.pattern, ctx.posix):
                continue
            if scope.functions is not None:
                wanted = set(scope.functions)
                for node in ast.walk(ctx.tree):
                    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                            and node.name in wanted:
                        yield node, scope.checks
            elif scope.entry is not None:
                cls_name, entry = scope.entry
                for node in ast.walk(ctx.tree):
                    if isinstance(node, ast.ClassDef) and node.name == cls_name:
                        for meth in _reachable_methods(node, entry):
                            yield meth, scope.checks
            else:
                yield ctx.tree, scope.checks
            return  # first matching scope wins


def _reachable_methods(cls: ast.ClassDef, entry: str) -> list:
    """Methods of ``cls`` reachable from ``entry`` via self-references."""
    methods = {n.name: n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    seen, stack = {entry}, [entry]
    while stack:
        m = methods.get(stack.pop())
        if m is None:
            continue
        for node in ast.walk(m):
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.value, ast.Name) and \
                    node.value.id == "self" and node.attr in methods and \
                    node.attr not in seen:
                seen.add(node.attr)
                stack.append(node.attr)
    return [methods[n] for n in sorted(seen) if n in methods]


class _Scan(ast.NodeVisitor):
    """Walk one hot region, tracking per-function host-taint."""

    def __init__(self, checker: HostSyncChecker, ctx: FileContext,
                 checks: frozenset):
        self.checker = checker
        self.ctx = ctx
        self.checks = checks
        self.aliases = import_aliases(ctx.tree)
        self.jnp = {name for name, mod in self.aliases.items()
                    if mod == "jax.numpy"}
        self.taint: list[set] = []   # stack of per-function host-name sets
        self.out: list[Violation] = []

    def run(self, region: ast.AST) -> list[Violation]:
        if isinstance(region, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._visit_function(region)
        else:
            self.visit(region)
        return self.out

    # --------------------------------------------------------------- taint
    def _is_host(self, node: ast.AST) -> bool:
        """Conservatively true when ``node`` is a host-side value."""
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Name):
            return any(node.id in t for t in self.taint)
        if isinstance(node, (ast.Attribute, ast.Subscript)):
            return self._is_host(node.value)
        if isinstance(node, ast.Call):
            name = resolve(dotted(node.func), self.aliases)
            if name and (name.startswith(_HOST_ROOTS) or
                         name in ("len", "sorted", "min", "max", "abs",
                                  "range", "enumerate", "sum")):
                return True
            if name in _COERCIONS:
                # float(x) is host-valued only if x already was -- otherwise
                # the coercion is itself the sync and must stay flaggable
                # (e.g. ``k = int(k)`` must not self-taint k).
                return bool(node.args) and self._is_host(node.args[0])
            return False
        if isinstance(node, ast.BinOp):
            return self._is_host(node.left) and self._is_host(node.right)
        if isinstance(node, (ast.Tuple, ast.List)):
            return all(self._is_host(e) for e in node.elts)
        if isinstance(node, ast.Compare):
            return self._is_host(node.left) and \
                all(self._is_host(c) for c in node.comparators)
        if isinstance(node, ast.IfExp):
            return self._is_host(node.body) and self._is_host(node.orelse)
        return False

    def _taint_function(self, fn) -> set:
        """Forward pass over ``fn``'s own statements (not nested defs)
        collecting names bound to host-side values."""
        host: set[str] = set()
        self.taint.append(host)

        def walk(stmts):
            for st in stmts:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                    continue
                if isinstance(st, ast.Assign) and self._is_host(st.value):
                    for t in st.targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                host.add(n.id)
                for field in ("body", "orelse", "finalbody"):
                    walk(getattr(st, field, []) or [])
                for h in getattr(st, "handlers", []) or []:
                    walk(h.body)
                for item in getattr(st, "items", []) or []:
                    pass
        walk(fn.body)
        self.taint.pop()
        return host

    # -------------------------------------------------------------- visits
    def _visit_function(self, fn) -> None:
        self.taint.append(self._taint_function(fn))
        for st in fn.body:
            self.visit(st)
        self.taint.pop()

    def visit_FunctionDef(self, node) -> None:
        self._visit_function(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def _flag(self, node, msg: str) -> None:
        self.out.append(self.checker.violation(self.ctx, node, msg))

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if config.SYNC in self.checks:
            if isinstance(func, ast.Attribute) and func.attr in _SYNC_ATTRS:
                self._flag(node, f"host sync: `{func.attr}()` "
                                 f"({_SYNC_ATTRS[func.attr]}) in the hot path")
            name = resolve(dotted(func), self.aliases)
            if name in ("numpy.asarray", "numpy.array") and node.args and \
                    not self._is_host(node.args[0]) and \
                    not _contains_explicit_fetch(node.args[0]):
                self._flag(node, "host sync: np.asarray of a (possibly) "
                                 "device value materializes on the host")
            if name == "print":
                self._flag(node, "host I/O: print() in the hot path "
                                 "(route through obs.Tracer/metrics instead)")
        if config.COERCE in self.checks and isinstance(func, ast.Name) and \
                func.id in _COERCIONS and len(node.args) == 1 and \
                not isinstance(node.args[0], ast.Constant) and \
                not self._is_host(node.args[0]):
            self._flag(node, f"implicit sync: `{func.id}()` of a (possibly) "
                             "device value blocks on the transfer")
        self.generic_visit(node)

    def _check_test(self, node, test: ast.AST, kind: str) -> None:
        if config.BRANCH not in self.checks:
            return
        for sub in ast.walk(test):
            if not isinstance(sub, ast.Call):
                continue
            func = sub.func
            if isinstance(func, ast.Attribute) and \
                    func.attr in ("any", "all") and not sub.args and \
                    not self._is_host(func.value):
                self._flag(node, f"branch on device value: `{kind}` over "
                                 f"`.{func.attr}()` forces a host bool() "
                                 "(use jnp.where / lax.cond)")
                return
            name = dotted(func)
            if name:
                head, _, rest = name.partition(".")
                if head in self.jnp and rest and \
                        rest not in config.HOST_SAFE_JNP:
                    self._flag(node, f"branch on device value: `{kind}` test "
                                     f"calls `{name}` (implicit bool() sync; "
                                     "retraces under jit)")
                    return

    def visit_If(self, node: ast.If) -> None:
        self._check_test(node, node.test, "if")
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._check_test(node, node.test, "while")
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        self._check_test(node, node.test, "assert")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        self._check_test(node, node.test, "ternary")
        self.generic_visit(node)


def _contains_explicit_fetch(node: ast.AST) -> bool:
    """True when the expression already routes through jax.device_get --
    the asarray around it is then host-side bookkeeping, and the device_get
    itself is the (separately flagged) sync."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute) \
                and sub.func.attr == "device_get":
            return True
    return False
