"""RL005 -- ``interpret=True`` literal-default lint for jitted kernels.

The bug class this kills: a Pallas kernel wrapper grows an
``interpret: bool = True`` default during bring-up (interpreter mode works
everywhere), ships, and then silently runs the 100x-slower interpreter on
hardware that could compile it. It happened once to ``deis_step`` and the
default then spread by copy-paste into ``flash_attention``/``ssd_scan``'s
jitted signatures.

The rule: a jitted function (``@jax.jit``/``@jax.pmap`` decorated, the
``functools.partial(jax.jit, ...)`` decorator form, or a local def passed
to a ``jax.jit(...)`` call) must not default an ``interpret``-flavored
parameter to a literal ``True``. The correct shape is ``interpret=None``
resolved at call time through the per-kernel capability table
(:func:`repro.kernels.runtime.default_interpret`) -- compiled wherever a
lowering exists, interpreter only as the fallback. Marking the parameter
static does not excuse the default: the cache key is fine, the VALUE is
the bug.
"""
from __future__ import annotations

import ast
from typing import Iterable, Sequence

from .base import Checker, FileContext, Violation, dotted, import_aliases, resolve

_PARAM_NAMES = ("interpret",)


def _interpret_true_params(fn) -> list:
    """``interpret``-flavored params of ``fn`` defaulting to literal True."""
    args = fn.args
    defaults = dict(zip([a.arg for a in args.args[-len(args.defaults):]],
                        args.defaults)) if args.defaults else {}
    defaults.update({a.arg: d for a, d in
                     zip(args.kwonlyargs, args.kw_defaults) if d})
    hits = []
    for a in args.args + args.kwonlyargs:
        if a.arg not in _PARAM_NAMES:
            continue
        dflt = defaults.get(a.arg)
        if isinstance(dflt, ast.Constant) and dflt.value is True:
            hits.append(a.arg)
    return hits


class InterpretDefaultChecker(Checker):
    rule = "RL005"
    title = "interpret=True literal default in a jitted kernel signature"

    def check(self, ctxs: Sequence[FileContext]) -> Iterable[Violation]:
        for ctx in ctxs:
            if ctx.tree is not None:
                yield from _Scan(self, ctx).run()


class _Scan(ast.NodeVisitor):
    def __init__(self, checker: InterpretDefaultChecker, ctx: FileContext):
        self.checker = checker
        self.ctx = ctx
        self.aliases = import_aliases(ctx.tree)
        self.scopes: list[dict] = [{}]          # name -> FunctionDef
        self.out: list[Violation] = []

    def run(self) -> list[Violation]:
        self.visit(self.ctx.tree)
        return self.out

    def _flag(self, node, fn) -> None:
        for name in _interpret_true_params(fn):
            self.out.append(self.checker.violation(
                self.ctx, node,
                f"jitted `{fn.name}` defaults `{name}=True`: the kernel "
                "silently runs the interpreter on backends that could "
                "compile it -- default None and resolve through the "
                "per-kernel capability table"))

    def _is_jit_name(self, node) -> bool:
        return resolve(dotted(node), self.aliases) in ("jax.jit", "jax.pmap")

    def visit_FunctionDef(self, node) -> None:
        self.scopes[-1][node.name] = node
        for dec in node.decorator_list:
            if self._is_jit_name(dec):
                self._flag(node, node)
            elif isinstance(dec, ast.Call) and (
                    self._is_jit_name(dec.func) or
                    (resolve(dotted(dec.func), self.aliases) ==
                     "functools.partial" and dec.args and
                     self._is_jit_name(dec.args[0]))):
                self._flag(node, node)
        self.scopes.append({})
        self.generic_visit(node)
        self.scopes.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call) -> None:
        # jax.jit(fn) / functools.partial(jax.jit, fn) over a resolvable def
        target = None
        if self._is_jit_name(node.func) and node.args:
            target = node.args[0]
        elif resolve(dotted(node.func), self.aliases) == \
                "functools.partial" and node.args and \
                self._is_jit_name(node.args[0]) and len(node.args) > 1:
            target = node.args[1]
        if isinstance(target, ast.Name):
            for scope in reversed(self.scopes):
                if target.id in scope:
                    self._flag(node, scope[target.id])
                    break
        self.generic_visit(node)
