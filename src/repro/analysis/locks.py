"""RL003 -- serving lock-discipline checker (a lightweight race detector).

The serving stack has exactly one threading contract (driver module
docstring): ONE scheduler thread owns the engine and JAX; transport threads
only enqueue and wait on futures. ``config.OWNERSHIP`` turns that prose
into a table -- every attribute of ``DiffusionServeEngine`` /
``ServeDriver`` / ``MetricsRegistry`` is declared config (immutable),
scheduler-thread-only, lock-protected, or atomic -- and this checker
enforces it structurally:

* every method is classified *transport* (reachable from the public
  thread-safe entry points, through self-calls and the driver->engine
  delegate edge) and/or *scheduler* (reachable from the tick loop);
* an access to a ``scheduler`` attribute from a transport-reachable method
  is a data race with the tick loop -> violation;
* any access to a ``locked`` attribute outside a ``with self.<lock>:``
  block (anywhere but ``__init__``) -> violation;
* a ``config`` attribute reassigned outside ``__init__`` -> violation;
* an attribute assigned anywhere in the class but missing from the table
  -> violation, so the table can never silently rot.

``__init__`` is exempt from context rules: construction happens-before the
scheduler thread exists.
"""
from __future__ import annotations

import ast
import fnmatch
from typing import Iterable, Optional, Sequence

from .base import Checker, FileContext, Violation
from .config import OWNERSHIP, Ownership

_METHOD_TYPES = (ast.FunctionDef, ast.AsyncFunctionDef)


def _classify(spec: Ownership, attr: str) -> Optional[str]:
    for bucket in ("config", "scheduler", "locked", "atomic"):
        if any(fnmatch.fnmatch(attr, pat) for pat in getattr(spec, bucket)):
            return bucket
    return None


class _Class:
    """One ownership-tabled class found in the target set."""

    def __init__(self, ctx: FileContext, node: ast.ClassDef, spec: Ownership):
        self.ctx = ctx
        self.node = node
        self.spec = spec
        self.methods = {n.name: n for n in node.body
                        if isinstance(n, _METHOD_TYPES)}
        self.contexts: dict[str, set] = {m: set() for m in self.methods}

    def entry_methods(self, names: tuple) -> list:
        if "*" in names:
            return list(self.methods)
        return [n for n in names if n in self.methods]


class LockDisciplineChecker(Checker):
    rule = "RL003"
    title = "serving lock discipline (ownership table vs method call graphs)"

    def check(self, ctxs: Sequence[FileContext]) -> Iterable[Violation]:
        classes: dict[str, _Class] = {}
        for ctx in ctxs:
            if ctx.tree is None:
                continue
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.ClassDef) and node.name in OWNERSHIP:
                    classes[node.name] = _Class(ctx, node, OWNERSHIP[node.name])
        if not classes:
            return
        self._propagate_contexts(classes)
        for cls in classes.values():
            yield from self._check_class(cls, classes)

    # -------------------------------------------------- context propagation
    def _propagate_contexts(self, classes: dict) -> None:
        work: list[tuple[str, str, str]] = []
        for name, cls in classes.items():
            for m in cls.entry_methods(cls.spec.transport_entries):
                work.append((name, m, "transport"))
            for m in cls.entry_methods(cls.spec.scheduler_entries):
                work.append((name, m, "scheduler"))
        while work:
            cname, meth, tag = work.pop()
            cls = classes[cname]
            if meth not in cls.methods or tag in cls.contexts[meth]:
                continue
            # Declared entry points PIN their context: a reference like
            # ``threading.Thread(target=self._run)`` inside a transport
            # method is the thread boundary itself, not a transport call
            # into the scheduler loop.
            if (meth in cls.spec.scheduler_entries and tag != "scheduler") \
                    or (meth in cls.spec.transport_entries and
                        tag != "transport"):
                continue
            cls.contexts[meth].add(tag)
            for tgt_cls, tgt_meth in self._edges(cls, cls.methods[meth],
                                                 classes):
                work.append((tgt_cls, tgt_meth, tag))

    def _edges(self, cls: _Class, fn, classes: dict):
        """(class, method) references made by ``fn``: self-calls, property
        reads, and delegate-object member references."""
        delegate_aliases = self._delegate_aliases(cls, fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Attribute):
                continue
            base = node.value
            if isinstance(base, ast.Name) and base.id == "self":
                if node.attr in cls.methods:
                    yield cls.node.name, node.attr
                elif node.attr in cls.spec.delegates:
                    pass  # handled via the chained-attribute case below
            # self.<delegate>.member  or  alias.member
            target_cls = None
            if isinstance(base, ast.Attribute) and \
                    isinstance(base.value, ast.Name) and \
                    base.value.id == "self" and \
                    base.attr in cls.spec.delegates:
                target_cls = cls.spec.delegates[base.attr]
            elif isinstance(base, ast.Name) and base.id in delegate_aliases:
                target_cls = delegate_aliases[base.id]
            if target_cls and target_cls in classes and \
                    node.attr in classes[target_cls].methods:
                yield target_cls, node.attr

    def _delegate_aliases(self, cls: _Class, fn) -> dict:
        """Local names bound to a delegate object (``eng = self.engine``)."""
        out: dict[str, str] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Attribute) and \
                    isinstance(node.value.value, ast.Name) and \
                    node.value.value.id == "self" and \
                    node.value.attr in cls.spec.delegates:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out[t.id] = cls.spec.delegates[node.value.attr]
        return out

    # ------------------------------------------------------------ checking
    def _check_class(self, cls: _Class, classes: dict) -> Iterable[Violation]:
        seen_unclassified: set = set()
        for name, fn in cls.methods.items():
            yield from self._check_method(cls, name, fn, classes,
                                          seen_unclassified)

    def _check_method(self, cls: _Class, name: str, fn, classes: dict,
                      seen_unclassified: set) -> Iterable[Violation]:
        spec = cls.spec
        ctx = cls.ctx
        in_init = name == "__init__"
        transport = "transport" in cls.contexts[name]
        delegate_aliases = self._delegate_aliases(cls, fn)

        def walk(node, locked: bool, stored: set):
            if isinstance(node, ast.With):
                holds = locked or any(
                    isinstance(it.context_expr, ast.Attribute) and
                    isinstance(it.context_expr.value, ast.Name) and
                    it.context_expr.value.id == "self" and
                    it.context_expr.attr == spec.lock
                    for it in node.items)
                for it in node.items:
                    yield from walk(it.context_expr, locked, stored)
                for st in node.body:
                    yield from walk(st, holds, stored)
                return
            if isinstance(node, ast.Attribute):
                yield from check_attr(node, cls, spec, locked, stored)
            elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    for sub in ast.walk(t):
                        if isinstance(sub, ast.Attribute) and \
                                isinstance(sub.value, ast.Name) and \
                                sub.value.id == "self":
                            stored.add(sub.attr)
                            yield from check_store(sub)
            for child in ast.iter_child_nodes(node):
                yield from walk(child, locked, stored)

        def check_store(node: ast.Attribute):
            attr = node.attr
            bucket = _classify(cls.spec, attr)
            if bucket is None and attr not in cls.methods and \
                    attr not in seen_unclassified:
                seen_unclassified.add(attr)
                yield self.violation(
                    ctx, node, f"`{cls.node.name}.{attr}` is not in the "
                    "ownership table: declare it config / scheduler / "
                    "locked / atomic in repro.analysis.config.OWNERSHIP")
            if bucket == "config" and not in_init:
                yield self.violation(
                    ctx, node, f"config attribute `{attr}` reassigned in "
                    f"`{name}` -- config is immutable after __init__")

        def check_attr(node: ast.Attribute, owner: _Class, owner_spec,
                       locked: bool, stored: set):
            base = node.value
            target_cls = None
            if isinstance(base, ast.Name) and base.id == "self" and \
                    owner is cls:
                target_cls, target_spec = owner, owner_spec
            elif isinstance(base, ast.Attribute) and \
                    isinstance(base.value, ast.Name) and \
                    base.value.id == "self" and \
                    base.attr in spec.delegates and \
                    spec.delegates[base.attr] in classes:
                target_cls = classes[spec.delegates[base.attr]]
                target_spec = target_cls.spec
            elif isinstance(base, ast.Name) and base.id in delegate_aliases \
                    and delegate_aliases[base.id] in classes:
                target_cls = classes[delegate_aliases[base.id]]
                target_spec = target_cls.spec
            if target_cls is None:
                return
            attr = node.attr
            if attr in target_cls.methods:
                return
            bucket = _classify(target_spec, attr)
            if bucket == "locked" and not (locked and target_cls is cls) \
                    and not in_init:
                lock = target_spec.lock or "<lock>"
                where = f"`{target_cls.node.name}.{attr}`"
                yield self.violation(
                    ctx, node, f"lock-protected {where} accessed outside "
                    f"`with self.{lock}:` in `{cls.node.name}.{name}` -- "
                    "racy against the other side of the lock")
            elif bucket == "scheduler" and transport and not in_init:
                yield self.violation(
                    ctx, node, f"scheduler-thread-only "
                    f"`{target_cls.node.name}.{attr}` accessed from "
                    f"transport-reachable `{cls.node.name}.{name}` -- "
                    "races the tick loop")

        yield from walk(fn, False, set())
