"""RL004 -- plan-leaf guard: every coefficient key a ``plan_*`` builder
constructs must be classifiable by the role registries (the static twin of
PR 8's runtime registration guard in ``core/plan._leaf_role``).

``pad_plan``/``stack_plans``/``inert_row``/``take_rows`` and the sharding
specs all decide per-leaf behavior from ``_leaf_role(name, shape,
n_steps)``; a novel key that is not in ``_PER_STEP_COEFFS`` /
``_PER_KNOT_COEFFS`` / ``_STATIC_COEFFS`` falls back to a shape heuristic
that can misclassify it (a static tableau whose length happens to equal
``n_steps`` becomes "per-step" and gets padded/gathered). So:

* every key in a dict built inside a ``plan_*`` function (literal dicts
  handed to ``_mk``, ``coeffs[...] = ...`` stores, ``coeffs.update(...)``)
  must appear in one of the role registries;
* the primary registries must stay pairwise disjoint (a key in two roles
  is unclassifiable); modifier registries (``_TIME_LIKE``) must be subsets
  of a primary one;
* the ``SamplerState(...)`` constructed by ``sharding/rules.state_specs``
  must name every field of ``core/sampler.SamplerState`` -- a new state
  field without a sharding spec would silently replicate (and a typo'd
  field would crash at serve time, not at review time).

The checker is project-level: registries may live in one file (core/plan)
and builders/specs in others; a self-contained fixture file works too.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional, Sequence

from .base import Checker, FileContext, Violation
from .config import MODIFIER_REGISTRIES, ROLE_REGISTRIES


def _frozenset_literal(node: ast.AST) -> Optional[set]:
    """The string set of ``frozenset({...})`` / ``frozenset((...))``."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) and \
            node.func.id == "frozenset" and node.args:
        elts = getattr(node.args[0], "elts", None)
        if elts is not None and all(isinstance(e, ast.Constant) and
                                    isinstance(e.value, str) for e in elts):
            return {e.value for e in elts}
    return None


class PlanLeafChecker(Checker):
    rule = "RL004"
    title = "plan-leaf guard (coefficient keys vs role registries and sharding specs)"

    def check(self, ctxs: Sequence[FileContext]) -> Iterable[Violation]:
        registries: dict[str, tuple[FileContext, ast.AST, set]] = {}
        builders: list[tuple[FileContext, ast.FunctionDef]] = []
        state_fields: Optional[tuple[FileContext, ast.ClassDef, list]] = None
        spec_calls: list[tuple[FileContext, ast.Call]] = []

        for ctx in ctxs:
            if ctx.tree is None:
                continue
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    name = node.targets[0].id
                    if name in ROLE_REGISTRIES + MODIFIER_REGISTRIES:
                        keys = _frozenset_literal(node.value)
                        if keys is not None:
                            registries[name] = (ctx, node, keys)
                elif isinstance(node, ast.FunctionDef):
                    if node.name.startswith("plan_"):
                        builders.append((ctx, node))
                    elif node.name == "state_specs":
                        for sub in ast.walk(node):
                            if isinstance(sub, ast.Call) and \
                                    isinstance(sub.func, ast.Name) and \
                                    sub.func.id == "SamplerState":
                                spec_calls.append((ctx, sub))
                elif isinstance(node, ast.ClassDef) and \
                        node.name == "SamplerState":
                    fields = [st.target.id for st in node.body
                              if isinstance(st, ast.AnnAssign) and
                              isinstance(st.target, ast.Name)]
                    if fields:
                        state_fields = (ctx, node, fields)

        if registries:
            yield from self._check_registry_shape(registries)
            known = set().union(*(r[2] for r in registries.values()))
            for ctx, fn in builders:
                yield from self._check_builder(ctx, fn, known)
        if state_fields and spec_calls:
            yield from self._check_state_specs(state_fields, spec_calls)

    # ---------------------------------------------------------- registries
    def _check_registry_shape(self, registries) -> Iterable[Violation]:
        primaries = [(n, *registries[n]) for n in ROLE_REGISTRIES
                     if n in registries]
        for i, (na, ctxa, nodea, a) in enumerate(primaries):
            for nb, ctxb, nodeb, b in primaries[i + 1:]:
                overlap = a & b
                if overlap:
                    yield self.violation(
                        ctxb, nodeb, f"key(s) {sorted(overlap)} appear in "
                        f"both {na} and {nb}: the leaf role is ambiguous")
        primary_union = set().union(*(p[3] for p in primaries)) \
            if primaries else set()
        for name in MODIFIER_REGISTRIES:
            if name in registries:
                ctx, node, keys = registries[name]
                stray = keys - primary_union
                if stray:
                    yield self.violation(
                        ctx, node, f"modifier registry {name} names key(s) "
                        f"{sorted(stray)} that no primary registry "
                        "classifies -- they would never match")

    # ------------------------------------------------------------ builders
    def _check_builder(self, ctx, fn: ast.FunctionDef,
                       known: set) -> Iterable[Violation]:
        coeff_names = {"coeffs"}
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id == "_mk" and len(node.args) >= 2 and \
                    isinstance(node.args[1], ast.Name):
                coeff_names.add(node.args[1].id)

        def keys_of(d: ast.Dict):
            for k in d.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    yield k.value, k

        found: list[tuple[str, ast.AST]] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Dict) and any(
                        isinstance(t, ast.Name) and t.id in coeff_names
                        for t in node.targets):
                found.extend(keys_of(node.value))
            elif isinstance(node, ast.Call):
                func = node.func
                if isinstance(func, ast.Name) and func.id == "_mk" and \
                        len(node.args) >= 2 and \
                        isinstance(node.args[1], ast.Dict):
                    found.extend(keys_of(node.args[1]))
                elif isinstance(func, ast.Attribute) and \
                        func.attr == "update" and \
                        isinstance(func.value, ast.Name) and \
                        func.value.id in coeff_names:
                    for kw in node.keywords:
                        if kw.arg:
                            found.append((kw.arg, node))
                    for arg in node.args:
                        if isinstance(arg, ast.Dict):
                            found.extend(keys_of(arg))
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Subscript) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id in coeff_names and \
                            isinstance(t.slice, ast.Constant) and \
                            isinstance(t.slice.value, str):
                        found.append((t.slice.value, t))
        for key, node in found:
            if key not in known:
                yield self.violation(
                    ctx, node, f"coefficient key '{key}' built by "
                    f"`{fn.name}` is in no role registry -- register it in "
                    "_PER_STEP_COEFFS / _PER_KNOT_COEFFS / _STATIC_COEFFS "
                    "so _leaf_role and the sharding specs classify it")

    # --------------------------------------------------------- state specs
    def _check_state_specs(self, state_fields, spec_calls
                           ) -> Iterable[Violation]:
        _, _, fields = state_fields
        for ctx, call in spec_calls:
            covered = set(f for f, _ in zip(fields, call.args))
            covered |= {kw.arg for kw in call.keywords if kw.arg}
            missing = [f for f in fields if f not in covered]
            unknown = sorted(covered - set(fields))
            if missing:
                yield self.violation(
                    ctx, call, "state_specs' SamplerState(...) misses "
                    f"field(s) {missing}: a new SamplerState field needs a "
                    "sharding spec or it silently replicates")
            if unknown:
                yield self.violation(
                    ctx, call, f"state_specs names unknown SamplerState "
                    f"field(s) {unknown}")
