"""RL002 -- recompile-hazard lint for every ``jax.jit`` call site.

Warm serving traffic must converge to a fixed executor set (the engine
asserts zero warm recompiles in its benchmarks); these are the static
hazards that silently break that:

* ``jax.jit`` (or ``functools.partial(jax.jit, ...)``) called inside a
  ``for``/``while`` loop -- a fresh jitted callable per iteration means a
  fresh trace per iteration (worse with a lambda/local def: the cache can
  never hit across iterations);
* a jitted function whose free variables include an enclosing loop's
  target -- the Python scalar is captured at trace time and silently
  stale (or retraces) on later iterations;
* ``static_argnums``/``static_argnames`` given as a non-literal -- the
  compile-cache key then depends on runtime state;
* a resolvable jitted def with bool/str-flavored parameters (annotation or
  default) that are not marked static -- they either retrace per value or
  fail under tracing;
* f-strings or unsorted ``.items()`` iteration feeding keys of a
  ``*cache*``/``*compiled*`` mapping -- formatting collapses distinct
  dtypes/values into one key, and dict order makes equal plans miss.
"""
from __future__ import annotations

import ast
import re
from typing import Iterable, Optional, Sequence

from .base import Checker, FileContext, Violation, dotted, import_aliases, resolve

_CACHE_NAME = re.compile(r"cache|compiled", re.IGNORECASE)
_STATIC_KWARGS = ("static_argnums", "static_argnames")


def _is_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return all(_is_literal(e) for e in node.elts)
    return False


def _free_names(fn) -> set:
    """Names a def reads but does not bind (approximate closure capture)."""
    bound = {a.arg for a in (fn.args.args + fn.args.kwonlyargs +
                             fn.args.posonlyargs)}
    if fn.args.vararg:
        bound.add(fn.args.vararg.arg)
    if fn.args.kwarg:
        bound.add(fn.args.kwarg.arg)
    loads, stores = set(), set(bound)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name):
            if isinstance(node.ctx, ast.Load):
                loads.add(node.id)
            else:
                stores.add(node.id)
    return loads - stores


class RecompileChecker(Checker):
    rule = "RL002"
    title = "recompile-hazard lint (jit call sites and compile-cache keys)"

    def check(self, ctxs: Sequence[FileContext]) -> Iterable[Violation]:
        for ctx in ctxs:
            if ctx.tree is not None:
                yield from _JitScan(self, ctx).run()


class _JitScan(ast.NodeVisitor):
    def __init__(self, checker: RecompileChecker, ctx: FileContext):
        self.checker = checker
        self.ctx = ctx
        self.aliases = import_aliases(ctx.tree)
        self.loops: list[ast.AST] = []          # enclosing loop stack
        self.loop_targets: list[set] = []       # their target names
        self.scopes: list[dict] = [{}]          # name -> FunctionDef
        self.out: list[Violation] = []

    def run(self) -> list[Violation]:
        self.visit(self.ctx.tree)
        return self.out

    def _flag(self, node, msg: str) -> None:
        self.out.append(self.checker.violation(self.ctx, node, msg))

    # ---------------------------------------------------------- structure
    def visit_FunctionDef(self, node) -> None:
        self.scopes[-1][node.name] = node
        self._check_decorators(node)
        self.scopes.append({})
        loops, targets = self.loops, self.loop_targets
        self.loops, self.loop_targets = [], []   # loops don't cross scopes
        self.generic_visit(node)
        self.loops, self.loop_targets = loops, targets
        self.scopes.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _visit_loop(self, node, targets: set) -> None:
        self.loops.append(node)
        self.loop_targets.append(targets)
        self.generic_visit(node)
        self.loops.pop()
        self.loop_targets.pop()

    def visit_For(self, node: ast.For) -> None:
        names = {n.id for n in ast.walk(node.target)
                 if isinstance(n, ast.Name)}
        self._visit_loop(node, names)

    visit_AsyncFor = visit_For

    def visit_While(self, node: ast.While) -> None:
        self._visit_loop(node, set())

    def _check_decorators(self, node) -> None:
        """``@jax.jit`` / ``@functools.partial(jax.jit, ...)`` decorators:
        the jitted function is the decorated def itself."""
        for dec in node.decorator_list:
            if resolve(dotted(dec), self.aliases) in ("jax.jit", "jax.pmap"):
                self._check_missing_statics(dec, node, set())
            elif isinstance(dec, ast.Call) and \
                    resolve(dotted(dec.func), self.aliases) == \
                    "functools.partial" and dec.args and \
                    resolve(dotted(dec.args[0]), self.aliases) in \
                    ("jax.jit", "jax.pmap"):
                statics: set = set()
                for kw in dec.keywords:
                    if kw.arg in _STATIC_KWARGS:
                        if not _is_literal(kw.value):
                            self._flag(dec, f"`{kw.arg}` is not a literal: "
                                            "the compile-cache key depends "
                                            "on runtime state")
                        statics |= {e.value for e in ast.walk(kw.value)
                                    if isinstance(e, ast.Constant)
                                    and isinstance(e.value, str)}
                self._check_missing_statics(dec, node, statics)

    # ---------------------------------------------------------------- jit
    def _jit_call(self, node: ast.Call) -> Optional[ast.AST]:
        """Return the jitted-function expression when ``node`` is a
        ``jax.jit(...)`` or ``functools.partial(jax.jit, ...)`` call."""
        name = resolve(dotted(node.func), self.aliases)
        if name in ("jax.jit", "jax.pmap"):
            return node.args[0] if node.args else None
        if name == "functools.partial" and node.args and \
                resolve(dotted(node.args[0]), self.aliases) in ("jax.jit",
                                                                "jax.pmap"):
            return node.args[1] if len(node.args) > 1 else None
        return None

    def _resolve_def(self, target: Optional[ast.AST]):
        if isinstance(target, ast.Name):
            for scope in reversed(self.scopes):
                if target.id in scope:
                    return scope[target.id]
        return None

    def visit_Call(self, node: ast.Call) -> None:
        name = resolve(dotted(node.func), self.aliases)
        is_jit = name in ("jax.jit", "jax.pmap") or (
            name == "functools.partial" and node.args and
            resolve(dotted(node.args[0]), self.aliases) in ("jax.jit",
                                                            "jax.pmap"))
        if is_jit:
            target = self._jit_call(node)
            self._check_jit_site(node, target)
        self._check_cache_key(node)
        self.generic_visit(node)

    def _check_jit_site(self, node: ast.Call, target) -> None:
        fn = self._resolve_def(target)
        if self.loops:
            what = "a lambda" if isinstance(target, ast.Lambda) else \
                "a local def" if fn is not None else "a function"
            self._flag(node, f"jax.jit of {what} inside a loop: a fresh "
                             "jitted callable (and trace) per iteration -- "
                             "hoist the jit or key an executor cache")
        # Python-scalar closure capture of a loop variable
        free = None
        if isinstance(target, ast.Lambda):
            free = _free_names(target)
        elif fn is not None:
            free = _free_names(fn)
        if free:
            leaked = free & set().union(*self.loop_targets) \
                if self.loop_targets else set()
            if leaked:
                self._flag(node, "jitted function closes over loop "
                                 f"variable(s) {sorted(leaked)}: the value "
                                 "is baked at trace time and goes stale "
                                 "(pass it as an argument instead)")
        statics: set = set()
        for kw in node.keywords:
            if kw.arg in _STATIC_KWARGS:
                if not _is_literal(kw.value):
                    self._flag(node, f"`{kw.arg}` is not a literal: the "
                                     "compile-cache key depends on runtime "
                                     "state")
                statics |= {e.value for e in ast.walk(kw.value)
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str)}
        if fn is not None:
            self._check_missing_statics(node, fn, statics)

    def _check_missing_statics(self, node, fn, statics: set) -> None:
        args = fn.args
        defaults = dict(zip([a.arg for a in args.args[-len(args.defaults):]],
                            args.defaults)) if args.defaults else {}
        defaults.update({a.arg: d for a, d in
                         zip(args.kwonlyargs, args.kw_defaults) if d})
        for a in args.args + args.kwonlyargs:
            if a.arg in statics:
                continue
            ann = getattr(a.annotation, "id", None)
            dflt = defaults.get(a.arg)
            staticish = ann in ("bool", "str") or (
                isinstance(dflt, ast.Constant) and
                isinstance(dflt.value, (bool, str)))
            if staticish:
                self._flag(node, f"param `{a.arg}` of jitted `{fn.name}` "
                                 "looks static (bool/str) but is not in "
                                 "static_argnames -- it will retrace per "
                                 "value or fail under tracing")

    # --------------------------------------------------------- cache keys
    def _key_hazards(self, container: ast.AST, key: ast.AST) -> None:
        cname = dotted(container) or ""
        if not _CACHE_NAME.search(cname):
            return
        for sub in ast.walk(key):
            if isinstance(sub, ast.JoinedStr):
                self._flag(sub, f"f-string in compile-cache key of "
                                f"`{cname}`: formatting collapses distinct "
                                "dtypes/shapes into one key -- use a tuple")
                break
        has_items = any(isinstance(s, ast.Call) and
                        isinstance(s.func, ast.Attribute) and
                        s.func.attr == "items" for s in ast.walk(key))
        has_sorted = any(isinstance(s, ast.Call) and
                         dotted(s.func) == "sorted" for s in ast.walk(key))
        if has_items and not has_sorted:
            self._flag(key, f"dict-order hazard in compile-cache key of "
                            f"`{cname}`: `.items()` iteration order is "
                            "insertion order -- wrap in sorted()")

    def _check_cache_key(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("get", "setdefault", "pop") and node.args:
            self._key_hazards(node.func.value, node.args[0])

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            if isinstance(t, ast.Subscript):
                self._key_hazards(t.value, t.slice)
        self.generic_visit(node)
