"""Config system: dataclasses + dict/CLI overrides.

One ``ModelConfig`` describes any backbone in the zoo (dense / MoE / SSM /
hybrid / encoder-decoder / VLM). Architecture configs under ``repro/configs``
instantiate the exact assigned settings and cite their source.

Dict -> dataclass conversion is handled by a small local strict converter
(``config_from_dict``) so the package has no dependency beyond jax/numpy.
"""
from __future__ import annotations

import dataclasses
import importlib
import typing
from typing import Any, Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD (arXiv:2405.21060) minimal settings."""
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    source: str = ""          # citation for the assigned config
    arch_type: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0         # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    act: str = "silu"         # silu (SwiGLU) | gelu (GeGLU)
    glu: bool = True
    rope_theta: float = 10000.0
    sliding_window: int = 0   # 0 -> full attention
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0  # grok/gemma2-style tanh softcap, 0 = off
    # MoE / SSM / hybrid
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0       # hybrid: 1 attention layer per `attn_every` layers
    moe_every: int = 0        # hybrid/moe: MoE MLP every k-th layer (0 = all if moe set)
    # encoder-decoder (audio) / VLM prefix
    encoder_layers: int = 0
    encoder_seq: int = 0      # fixed frontend length (audio frames / image patches)
    prefix_tokens: int = 0    # VLM: image-patch prefix length
    # numerics / objective
    dtype: str = "bfloat16"
    objective: str = "diffusion"  # diffusion (paper-native) | ar
    # diffusion head
    time_emb_dim: int = 256
    # ---- performance levers (EXPERIMENTS.md §Perf; defaults = paper-faithful
    # baseline, flags = beyond-paper optimized variants) ----
    moe_dispatch: str = "einsum"   # einsum (GShard one-hot) | gather (sort-free
    #                                scatter/gather -- no O(S*E*C*D) dispatch matmul)
    ce_mode: str = "gather"        # gather (take_along_axis; all-gathers sharded
    #                                logits) | onehot (contraction -- psum only)
    act_shard_axes: Optional[tuple] = None  # mesh axes to PIN the MoE activation
    #                                batch dim to (with_sharding_constraint);
    #                                None = let GSPMD choose (baseline)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    def is_attn_layer(self, i: int) -> bool:
        if self.arch_type == "ssm":
            return False
        if self.arch_type == "hybrid":
            # jamba: 1 attention layer per attn_every (e.g. index 3 of each 8-block)
            return (i % self.attn_every) == (self.attn_every // 2)
        return True

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if self.moe_every and self.moe_every > 1:
            return (i % self.moe_every) == 1
        return True

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family (<=2 layers, d_model<=512,
        <=4 experts) per the assignment spec."""
        kw: dict[str, Any] = dict(
            n_layers=min(self.n_layers, 2 if self.arch_type != "hybrid" else self.attn_every),
            d_model=min(self.d_model, 256),
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32),
            prefix_tokens=min(self.prefix_tokens, 8),
            dtype="float32",
        )
        hd = 32
        n_heads = max(2, min(self.n_heads, 4))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        kw.update(n_heads=n_heads, n_kv_heads=n_kv, head_dim=hd)
        if self.sliding_window:
            kw["sliding_window"] = 16
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(self.moe, num_experts=min(self.moe.num_experts, 4))
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, state_dim=16, head_dim=16, chunk_size=16)
        return self.with_(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) workload."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
    # EXTRA (beyond the 4 assigned): one DEIS NFE in embedding space -- the
    # paper's own sampling workload, used for the paper-representative
    # §Perf hillclimb pair.
    "deis_4k": ShapeConfig("deis_4k", 4096, 256, "deis"),
}

ARCH_IDS = [
    "whisper_tiny", "h2o_danube_3_4b", "paligemma_3b", "mixtral_8x7b",
    "grok_1_314b", "mamba2_2p7b", "glm4_9b", "gemma_2b", "granite_3_8b",
    "jamba_1p5_large", "cifar10_scorenet",
]


def get_config(arch: str, **overrides) -> ModelConfig:
    """Load ``repro.configs.<arch>`` and apply overrides."""
    arch = arch.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg: ModelConfig = mod.get_config()
    if overrides:
        cfg = cfg.with_(**overrides)
    return cfg


def _strict_from_dict(cls, data: dict):
    """Strict dict -> dataclass: unknown keys raise, nested dataclasses recurse,
    lists destined for tuple fields are converted, obvious type mismatches raise."""
    if not isinstance(data, dict):
        raise TypeError(f"expected dict for {cls.__name__}, got {type(data).__name__}")
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = set(data) - set(fields)
    if unknown:
        raise ValueError(f"unknown keys {sorted(unknown)} for {cls.__name__}")
    hints = typing.get_type_hints(cls)
    kwargs: dict[str, Any] = {}
    for name, val in data.items():
        tp = hints[name]
        if typing.get_origin(tp) is typing.Union:  # Optional[X]
            non_none = [a for a in typing.get_args(tp) if a is not type(None)]
            if val is None:
                kwargs[name] = None
                continue
            tp = non_none[0]
        if dataclasses.is_dataclass(tp):
            kwargs[name] = _strict_from_dict(tp, val)
        elif tp is tuple or typing.get_origin(tp) is tuple:
            if not isinstance(val, (list, tuple)):
                raise TypeError(f"{cls.__name__}.{name}: expected list/tuple, "
                                f"got {type(val).__name__}")
            kwargs[name] = tuple(val)
        elif tp is float and isinstance(val, (int, float)) and not isinstance(val, bool):
            kwargs[name] = float(val)
        elif isinstance(tp, type) and not isinstance(val, tp):
            raise TypeError(f"{cls.__name__}.{name}: expected {tp.__name__}, "
                            f"got {type(val).__name__}")
        else:
            kwargs[name] = val
    return cls(**kwargs)


def config_from_dict(d: dict) -> ModelConfig:
    return _strict_from_dict(ModelConfig, d)
