"""Paper-native config: a small transformer score network over flattened
image patches, used by the faithful-reproduction experiments (the paper's own
UNet checkpoints are unavailable offline; DESIGN.md §3). Diffusion objective,
bidirectional."""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="cifar10-scorenet", source="DEIS paper (ICLR 2023)",
        arch_type="dense",
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=1024, vocab_size=256, act="gelu", glu=True,
        objective="diffusion", dtype="float32",
    )
