"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, 8 experts top-2, logit softcapping. [hf:xai-org/grok-1]"""
from .base import ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", source="hf:xai-org/grok-1", arch_type="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=32768, vocab_size=131072, act="gelu", glu=True,
        logit_softcap=30.0,
        moe=MoEConfig(num_experts=8, top_k=2),
    )
