"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000, llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", source="arXiv:2401.16818", arch_type="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
        d_ff=10240, vocab_size=32000, act="silu", glu=True,
        sliding_window=4096, rope_theta=10000.0,
    )
