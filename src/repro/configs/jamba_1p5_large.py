"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, Mamba:attention 7:1 interleave (1 attn layer per
8-layer block), MoE 16 experts top-2 on alternating layers.
[arXiv:2403.19887]"""
from .base import ModelConfig, MoEConfig, SSMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large", source="arXiv:2403.19887", arch_type="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab_size=65536, act="silu", glu=True,
        attn_every=8, moe_every=2,
        moe=MoEConfig(num_experts=16, top_k=2),
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                      chunk_size=256),
    )
