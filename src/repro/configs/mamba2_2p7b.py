"""mamba2-2.7b [ssm] — 64L d_model=2560, attention-free SSD (state-space
duality), ssm_state=128, vocab=50280. [arXiv:2405.21060]"""
from .base import ModelConfig, SSMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", source="arXiv:2405.21060", arch_type="ssm",
        n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab_size=50280,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                      chunk_size=256),
    )
