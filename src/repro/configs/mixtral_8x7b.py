"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, sliding-window attention. [arXiv:2401.04088]"""
from .base import ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", source="arXiv:2401.04088", arch_type="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=32000, act="silu", glu=True,
        sliding_window=4096,
        moe=MoEConfig(num_experts=8, top_k=2),
    )
