"""paligemma-3b [vlm] — gemma-2b-style decoder: 18L d_model=2048 8H (GQA kv=1,
MQA) d_ff=16384 vocab=257216, head_dim=256; SigLIP vision encoder stubbed as a
256-token patch-embedding prefix. [arXiv:2407.07726]"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", source="arXiv:2407.07726", arch_type="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
        d_ff=16384, vocab_size=257216, act="gelu", glu=True,
        prefix_tokens=256, tie_embeddings=True,
    )
