"""whisper-tiny [audio enc-dec] — 4L decoder (+4L encoder) d_model=384 6H
(GQA kv=6) d_ff=1536 vocab=51865, conv frontend stubbed as precomputed frame
embeddings (1500 frames). [arXiv:2212.04356]"""
from .base import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", source="arXiv:2212.04356", arch_type="encdec",
        n_layers=4, encoder_layers=4, encoder_seq=1500,
        d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
        d_ff=1536, vocab_size=51865, act="gelu", glu=False,
    )
