"""DEIS core: the paper's contribution as a composable JAX module."""
from .sde import SDE, VPSDE, VESDE, SubVPSDE, get_sde
from .schedules import get_timesteps, SCHEDULES
from .coeffs import ab_coefficients, ddim_coefficients_vp, naive_ei_coefficients, AB_WEIGHTS
from .solvers import (ABSolver, RKSolver, EulerSolver, EMSolver, DDIMSolver,
                      IPNDMSolver, PNDMSolver, make_solver, SOLVER_NAMES, SolverBase)
from .likelihood import nll_bits_per_dim

__all__ = [
    "SDE", "VPSDE", "VESDE", "SubVPSDE", "get_sde",
    "get_timesteps", "SCHEDULES",
    "ab_coefficients", "ddim_coefficients_vp", "naive_ei_coefficients", "AB_WEIGHTS",
    "ABSolver", "RKSolver", "EulerSolver", "EMSolver", "DDIMSolver",
    "IPNDMSolver", "PNDMSolver", "make_solver", "SOLVER_NAMES", "SolverBase",
    "nll_bits_per_dim",
]
