"""DEIS core: the paper's contribution as a composable JAX module.

The public sampling API is functional: a pure *plan builder* precomputes the
per-step exponential-integrator coefficients into an immutable
:class:`SolverPlan` pytree, and a single *executor* applies any plan:

    from repro.core import VPSDE, get_timesteps, make_plan, sample

    sde = VPSDE()
    plan = make_plan("tab3", sde, get_timesteps(sde, 10, "quadratic"))
    x0 = sample(plan, eps_fn, x_T)                   # full solve
    # -- or stream it step by step (serving / resumable solves):
    from repro.core import init_state, step
    st = init_state(plan, x_T)
    for k in range(plan.n_steps):
        st = step(plan, k, st, eps_fn)

Plans are jit/vmap/pjit-traced arguments: every plan with the same
``signature`` (method tag + coefficient shapes) shares one compiled
executor. The legacy class-based API is gone; ``make_solver`` survives only
as a deprecated alias for ``make_plan`` (see ``repro/core/solvers.py`` for
the migration map).
"""
from .sde import SDE, VPSDE, VESDE, SubVPSDE, get_sde
from .schedules import get_timesteps, SCHEDULES
from .coeffs import (ab_coefficients, ddim_coefficients_vp,
                     eps_norm_profile, naive_ei_coefficients,
                     sn_ab_coefficients, AB_WEIGHTS)
from .plan import (SolverPlan, cached_make_plan, inert_row, join_rows,
                   make_plan, pad_plan,
                   plan_ab, plan_dpm_multistep, plan_rk, plan_ddim,
                   plan_euler, plan_em, plan_ipndm, plan_pndm, plan_scire,
                   plan_seeds, plan_sndeis, solver_stages, stack_plans,
                   take_rows)
from .sampler import (Hooks, SamplerState, init_state, join_state_rows,
                      sample, shard_state, step, take_state_rows)
from .adaptive import (AdaptiveResult, AdaptiveRK23, RetirePolicy,
                       error_ratio, step_factor)
from .solvers import make_solver, SOLVER_NAMES
from .likelihood import nll_bits_per_dim

__all__ = [
    "SDE", "VPSDE", "VESDE", "SubVPSDE", "get_sde",
    "get_timesteps", "SCHEDULES",
    "ab_coefficients", "ddim_coefficients_vp", "eps_norm_profile",
    "naive_ei_coefficients", "sn_ab_coefficients", "AB_WEIGHTS",
    "SolverPlan", "cached_make_plan", "inert_row", "join_rows", "make_plan",
    "pad_plan",
    "plan_ab", "plan_dpm_multistep", "plan_rk", "plan_ddim", "plan_euler",
    "plan_em", "plan_ipndm", "plan_pndm", "plan_scire", "plan_seeds",
    "plan_sndeis", "solver_stages", "stack_plans", "take_rows",
    "Hooks", "SamplerState", "init_state", "join_state_rows", "sample",
    "shard_state", "step", "take_state_rows",
    "AdaptiveResult", "AdaptiveRK23", "RetirePolicy", "error_ratio",
    "step_factor",
    "make_solver", "SOLVER_NAMES",
    "nll_bits_per_dim",
]
