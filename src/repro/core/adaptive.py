"""Adaptive-step error control: the shared estimate/accept/rescale policy.

Two consumers sit on the same machinery:

* :class:`AdaptiveRK23` -- adaptive-step rhoRK (Bogacki-Shampine 3(2)) with
  rejection accounting, implementing the paper's App. B Q2 analysis:

      "Most existing adaptive step size strategies have some probability of
       getting rejected for the proposed step size, which will waste the NFE
       budget ... one rejection will waste 5 NFE, which is unacceptable when
       we try to generate samples in 10 NFE."

  We integrate the transformed non-stiff ODE dy/drho = eps_hat(y, rho)
  (Prop. 3) with an embedded 3(2) pair and PI step control, counting BOTH
  accepted and rejected evaluations. benchmarks/adaptive_bench.py shows the
  fixed-grid tAB-DEIS dominating at small budgets, reproducing the paper's
  argument quantitatively.

* :class:`RetirePolicy` -- the serving-side half of the same idea: fixed-grid
  plans built with ``error_estimate=True`` maintain a per-row local-error
  estimate in ``SamplerState.err`` (embedded lower-order pair, zero extra
  NFE), and the serving engine's boundary pass retires rows early once the
  estimate clears the policy's tolerance. Where AdaptiveRK23 *rescales* the
  step on the estimate, RetirePolicy *stops* on it -- both are thin policies
  over one error-norm, and neither spends NFEs on the estimate itself.

The shared pieces (:func:`error_ratio`, :func:`step_factor`) are module
functions so the two policies can never drift apart numerically.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .plan import _f64
from .sampler import SamplerState
from .sde import SDE


def error_ratio(y_hi, y_lo, y_prev, atol: float, rtol: float) -> float:
    """Scaled Linf error of an embedded pair: max |y_hi - y_lo| / scale with
    the standard elementwise scale ``atol + rtol * max(|y_hi|, |y_prev|)``.
    <= 1 means the step is acceptable at these tolerances."""
    return float(jnp.max(jnp.abs(y_hi - y_lo) /
                         (atol + rtol * jnp.maximum(
                             jnp.abs(y_hi), jnp.abs(y_prev)))))


def step_factor(err: float) -> float:
    """Classic third-order step rescale on an :func:`error_ratio` value:
    0.9 err^(-1/3), clipped to [0.2, 5]. err == 0 (exactly integrable eps,
    e.g. affine) takes the max growth."""
    return float(np.clip(0.9 * max(err, 1e-12) ** (-1 / 3), 0.2, 5.0))


@dataclasses.dataclass(frozen=True)
class RetirePolicy:
    """Early-exit decision over ``SamplerState.err`` (serving's boundary
    pass): a row whose running local-error estimate has dropped to ``tol``
    (absolute, or relative to the row's own Linf magnitude) after at least
    ``min_k`` of its own steps is converged and retires early.

    The decision is a pure per-row function of ``(err, k_own, |x|_inf)`` --
    nothing about the group a row is batched with enters it -- which is what
    keeps early-exit serving bitwise-vs-solo: a solo solve under the same
    policy retires at the identical step. Rows whose plan carries no embedded
    pair report ``err == +inf`` and never converge here.
    """

    tol: float
    min_k: int = 2        # floor of own-steps before the estimate is trusted
    norm: str = "abs"     # "abs": err <= tol; "rel": err <= tol * |x|_inf

    def __post_init__(self):
        if not (self.tol > 0):
            raise ValueError(f"tol must be positive, got {self.tol!r}")
        if self.norm not in ("abs", "rel"):
            raise ValueError(f"norm must be 'abs' or 'rel', got {self.norm!r}")
        if self.min_k < 1:
            raise ValueError(f"min_k must be >= 1, got {self.min_k!r}")

    def converged(self, err, x_inf=None):
        """Elementwise convergence mask (host-side numpy; err/x_inf are
        per-row vectors or scalars). ``x_inf`` (per-row Linf of the iterate)
        is required for ``norm='rel'`` and ignored for ``norm='abs'``."""
        err = np.asarray(err, np.float64)
        if self.norm == "rel":
            if x_inf is None:
                raise ValueError("norm='rel' needs the per-row |x|_inf scale")
            bound = self.tol * np.maximum(np.asarray(x_inf, np.float64), 1e-12)
        else:
            bound = self.tol
        return np.isfinite(err) & (err <= bound)


@dataclasses.dataclass
class AdaptiveResult:
    """Adaptive solve outcome, unified on the executor's ``SamplerState``:
    ``state.x`` is the final iterate and ``state.k`` the accepted-step count,
    so downstream code treats fixed-grid and adaptive results uniformly."""

    state: SamplerState
    nfe: int          # total evals including rejected steps
    n_accepted: int
    n_rejected: int

    @property
    def x0(self) -> jax.Array:
        return self.state.x


class AdaptiveRK23:
    """Embedded Bogacki-Shampine 3(2) on the rho-ODE with adaptive steps.

    3 fresh evals per attempted step (FSAL reuse on accept). Not jittable
    end-to-end by design -- the control flow is host-side so that NFE
    accounting is exact (this is an analysis tool, not a production sampler;
    the paper's point is precisely that one should NOT serve with this).
    Standalone on purpose: it is the one solver that is NOT a
    :class:`~repro.core.plan.SolverPlan` (no fixed grid exists to
    precompute), so it never rode the legacy ``SolverBase`` machinery's
    plan delegation -- only its attribute layout, inlined here when the
    class shims were removed. Accept/reject and step rescaling go through
    the module-level :func:`error_ratio` / :func:`step_factor`, the same
    primitives serving's :class:`RetirePolicy` is built on.
    """

    def __init__(self, sde: SDE, rtol: float = 1e-2, atol: float = 1e-2,
                 max_steps: int = 1000, name: str = "rk23_adaptive"):
        self.name, self.nfe = name, -1     # nfe is data-dependent (see solve)
        self.sde = sde
        self.ts = _f64(np.array([sde.T, sde.t0]))
        self.rtol, self.atol, self.max_steps = rtol, atol, max_steps

    def solve(self, eps_fn, x_T) -> AdaptiveResult:
        sde = self.sde
        rho_hi = float(sde.rho(sde.T))
        rho_lo = float(sde.rho(sde.t0))
        mu_T = float(sde.mu(sde.T))

        def eval_eps(y, rho):
            t = float(sde.t_of_rho(np.array(rho)))
            mu = float(sde.mu(t))
            return eps_fn(mu * y, jnp.asarray(t, y.dtype))

        y = x_T / mu_T
        rho = rho_hi
        h = -(rho_hi - rho_lo) * 0.05   # initial step: 5% of the interval
        nfe = n_acc = n_rej = 0
        last_err = float("inf")          # y-space Linf of the last accepted pair
        k1 = eval_eps(y, rho)
        nfe += 1
        for _ in range(self.max_steps):
            if rho <= rho_lo * (1 + 1e-9):
                break
            h = -min(-h, rho - rho_lo)
            k2 = eval_eps(y + 0.5 * h * k1, rho + 0.5 * h)
            k3 = eval_eps(y + 0.75 * h * k2, rho + 0.75 * h)
            nfe += 2
            y3 = y + h * (2 / 9 * k1 + 1 / 3 * k2 + 4 / 9 * k3)
            k4 = eval_eps(y3, rho + h)
            nfe += 1
            y2 = y + h * (7 / 24 * k1 + 1 / 4 * k2 + 1 / 3 * k3 + 1 / 8 * k4)
            err = error_ratio(y3, y2, y, self.atol, self.rtol)
            if err <= 1.0:
                last_err = float(jnp.max(jnp.abs(y3 - y2)))
                y, rho, k1 = y3, rho + h, k4   # FSAL
                n_acc += 1
            else:
                n_rej += 1
            h = h * step_factor(err)
        mu_0 = float(self.sde.mu(self.sde.t0))
        x0 = mu_0 * y
        state = SamplerState(x=x0, hist=jnp.zeros((0,) + x0.shape, x0.dtype),
                             key=jax.random.PRNGKey(0), k=jnp.int32(n_acc),
                             err=jnp.asarray(mu_0 * last_err, x0.dtype))
        return AdaptiveResult(state, nfe, n_acc, n_rej)
