"""Adaptive-step rhoRK (Bogacki-Shampine 3(2), RK45-class) with rejection
accounting -- implements the paper's App. B Q2 analysis:

    "Most existing adaptive step size strategies have some probability of
     getting rejected for the proposed step size, which will waste the NFE
     budget ... one rejection will waste 5 NFE, which is unacceptable when we
     try to generate samples in 10 NFE."

We integrate the transformed non-stiff ODE dy/drho = eps_hat(y, rho)
(Prop. 3) with an embedded 3(2) pair and PI step control, counting BOTH
accepted and rejected evaluations. benchmarks/adaptive_bench.py shows the
fixed-grid tAB-DEIS dominating at small budgets, reproducing the paper's
argument quantitatively.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .plan import _f64
from .sampler import SamplerState
from .sde import SDE


@dataclasses.dataclass
class AdaptiveResult:
    """Adaptive solve outcome, unified on the executor's ``SamplerState``:
    ``state.x`` is the final iterate and ``state.k`` the accepted-step count,
    so downstream code treats fixed-grid and adaptive results uniformly."""

    state: SamplerState
    nfe: int          # total evals including rejected steps
    n_accepted: int
    n_rejected: int

    @property
    def x0(self) -> jax.Array:
        return self.state.x


class AdaptiveRK23:
    """Embedded Bogacki-Shampine 3(2) on the rho-ODE with adaptive steps.

    3 fresh evals per attempted step (FSAL reuse on accept). Not jittable
    end-to-end by design -- the control flow is host-side so that NFE
    accounting is exact (this is an analysis tool, not a production sampler;
    the paper's point is precisely that one should NOT serve with this).
    Standalone on purpose: it is the one solver that is NOT a
    :class:`~repro.core.plan.SolverPlan` (no fixed grid exists to
    precompute), so it never rode the legacy ``SolverBase`` machinery's
    plan delegation -- only its attribute layout, inlined here when the
    class shims were removed.
    """

    def __init__(self, sde: SDE, rtol: float = 1e-2, atol: float = 1e-2,
                 max_steps: int = 1000, name: str = "rk23_adaptive"):
        self.name, self.nfe = name, -1     # nfe is data-dependent (see solve)
        self.sde = sde
        self.ts = _f64(np.array([sde.T, sde.t0]))
        self.rtol, self.atol, self.max_steps = rtol, atol, max_steps

    def solve(self, eps_fn, x_T) -> AdaptiveResult:
        sde = self.sde
        rho_hi = float(sde.rho(sde.T))
        rho_lo = float(sde.rho(sde.t0))
        mu_T = float(sde.mu(sde.T))

        def eval_eps(y, rho):
            t = float(sde.t_of_rho(np.array(rho)))
            mu = float(sde.mu(t))
            return eps_fn(mu * y, jnp.asarray(t, y.dtype))

        y = x_T / mu_T
        rho = rho_hi
        h = -(rho_hi - rho_lo) * 0.05   # initial step: 5% of the interval
        nfe = n_acc = n_rej = 0
        k1 = eval_eps(y, rho)
        nfe += 1
        for _ in range(self.max_steps):
            if rho <= rho_lo * (1 + 1e-9):
                break
            h = -min(-h, rho - rho_lo)
            k2 = eval_eps(y + 0.5 * h * k1, rho + 0.5 * h)
            k3 = eval_eps(y + 0.75 * h * k2, rho + 0.75 * h)
            nfe += 2
            y3 = y + h * (2 / 9 * k1 + 1 / 3 * k2 + 4 / 9 * k3)
            k4 = eval_eps(y3, rho + h)
            nfe += 1
            y2 = y + h * (7 / 24 * k1 + 1 / 4 * k2 + 1 / 3 * k3 + 1 / 8 * k4)
            err = float(jnp.max(jnp.abs(y3 - y2) /
                                (self.atol + self.rtol * jnp.maximum(
                                    jnp.abs(y3), jnp.abs(y)))))
            if err <= 1.0:
                y, rho, k1 = y3, rho + h, k4   # FSAL
                n_acc += 1
            else:
                n_rej += 1
            # err == 0 (exactly integrable eps, e.g. affine): take the max growth
            h = h * float(np.clip(0.9 * max(err, 1e-12) ** (-1 / 3), 0.2, 5.0))
        x0 = float(self.sde.mu(self.sde.t0)) * y
        state = SamplerState(x=x0, hist=jnp.zeros((0,) + x0.shape, x0.dtype),
                             key=jax.random.PRNGKey(0), k=jnp.int32(n_acc))
        return AdaptiveResult(state, nfe, n_acc, n_rej)
