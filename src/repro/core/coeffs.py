r"""DEIS coefficient engine (paper Eqs. 11, 14, 15).

Every DEIS multistep update is a linear combination

    x_{t_next} = psi * x_{t_cur} + sum_j C_j * eps_theta(x_hist_j, t_hist_j),

where ``psi = mu(t_next)/mu(t_cur)`` and, using the identity

    (1/2) Psi(t_next, tau) g(tau)^2 / sigma(tau) dtau = mu(t_next) * drho(tau),

the polynomial-extrapolation coefficients reduce to

    C_j = mu(t_next) * \int_{rho(t_cur)}^{rho(t_next)} l_j(rho) drho,

with ``l_j`` the Lagrange basis over the history nodes, expressed either in the
``rho`` coordinate (rhoAB-DEIS -- the integral is an exact polynomial integral)
or in the ``t`` coordinate (tAB-DEIS -- evaluated through t(rho)).

We compute all integrals with fixed-order Gauss-Legendre quadrature per step
interval. For rhoAB the quadrature is *exact* (polynomial degree <= r << 2*Q-1);
for tAB it is accurate to quadrature error ~1e-14 for the smooth t(rho) maps of
VPSDE/VESDE. Coefficients are computed **once on the host in float64** and baked
into the jitted sampling loop as constants (paper: "calculated once ... reused
across batches").

Closed-form VPSDE r=0 coefficients (Prop. 2 / deterministic DDIM) are provided
separately and tested to match the quadrature to ~1e-12.
"""
from __future__ import annotations

import numpy as np

from .sde import SDE

_GL_POINTS = 48  # exact for polynomials up to degree 95


def _gauss_legendre(a: float, b: float, n: int = _GL_POINTS):
    """Nodes and weights for \\int_a^b on possibly reversed interval (a > b ok)."""
    x, w = np.polynomial.legendre.leggauss(n)
    nodes = 0.5 * (b - a) * x + 0.5 * (b + a)
    weights = 0.5 * (b - a) * w
    return nodes, weights


def _lagrange_basis(nodes: np.ndarray, j: int, x: np.ndarray) -> np.ndarray:
    """l_j(x) over the given nodes, numerically stable for few nodes (r <= 3)."""
    out = np.ones_like(x)
    for k in range(len(nodes)):
        if k == j:
            continue
        out = out * (x - nodes[k]) / (nodes[j] - nodes[k])
    return out


def ab_coefficients(sde: SDE, ts: np.ndarray, order: int, basis: str = "t") -> tuple[np.ndarray, np.ndarray]:
    r"""Coefficients for (t|rho)AB-DEIS of the given order.

    Args:
      sde: forward SDE.
      ts: decreasing times, shape (N+1,), ts[0]=T, ts[-1]=t0.
      order: polynomial order r (0 = DDIM).
      basis: 't' for tAB-DEIS, 'rho' for rhoAB-DEIS, 'lambda' for the
        half-log-SNR coordinate lambda = -log rho = log(mu/sigma). Lagrange
        extrapolation in lambda integrated against drho reproduces the
        DPM-Solver multistep updates (Lu et al. 2022, arXiv 2206.00927)
        exactly: drho = -exp(-lambda) dlambda turns
        mu' * int l_j(lambda(rho)) drho into the lambda-Taylor finite
        differences of DPM-Solver-2/3, so the "new" family is one more
        coordinate chart over the SAME quadrature engine.

    Returns:
      psi:  (N,)          linear-term weights mu(ts[k+1]) / mu(ts[k])
      C:    (N, order+1)  C[k, j] multiplies eps history eps(ts[k-j]); rows for
                          k < order use the warmup (lower effective order) and
                          are zero-padded (paper App. B Q3).
    """
    if basis not in ("t", "rho", "lambda"):
        raise ValueError(f"basis must be 't', 'rho' or 'lambda', got {basis!r}")
    ts = np.asarray(ts, dtype=np.float64)
    n = len(ts) - 1
    mu = np.asarray(sde.mu(ts), dtype=np.float64)
    rho = np.asarray(sde.rho(ts), dtype=np.float64)

    psi = mu[1:] / mu[:-1]
    C = np.zeros((n, order + 1), dtype=np.float64)
    for k in range(n):
        r_eff = min(order, k)
        hist_idx = np.array([k - j for j in range(r_eff + 1)])
        nodes_t = ts[hist_idx]
        nodes_rho = rho[hist_idx]
        q_rho, q_w = _gauss_legendre(rho[k], rho[k + 1])
        if basis == "rho":
            q_x = q_rho
            nodes = nodes_rho
        elif basis == "lambda":
            q_x = -np.log(q_rho)
            nodes = -np.log(nodes_rho)
        else:
            q_x = np.asarray(sde.t_of_rho(q_rho), dtype=np.float64)
            nodes = nodes_t
        for j in range(r_eff + 1):
            C[k, j] = mu[k + 1] * np.sum(q_w * _lagrange_basis(nodes, j, q_x))
    return psi, C


def eps_norm_profile(sde: SDE, t, data_var: float = 1.0) -> np.ndarray:
    """RMS eps magnitude profile ell(t) used by score-normalized DEIS
    (arXiv 2311.00157): under data with per-dim variance ``data_var`` the
    marginal-average eps RMS is sigma / sqrt(mu^2 v + sigma^2) (exactly
    sigma(t) for VP with unit data variance). SN-DEIS fits the polynomial to
    the *normalized* integrand eps/ell -- flat across t, so the Lagrange
    extrapolation is better conditioned over wide steps."""
    t = np.asarray(t, dtype=np.float64)
    mu = np.asarray(sde.mu(t), dtype=np.float64)
    sig = np.asarray(sde.sigma(t), dtype=np.float64)
    return sig / np.sqrt(mu ** 2 * data_var + sig ** 2)


def sn_ab_coefficients(sde: SDE, ts: np.ndarray, order: int,
                       basis: str = "t", data_var: float = 1.0
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    r"""Score-normalized DEIS coefficients (arXiv 2311.00157).

    The eps integrand is split as eps(tau) = ell(tau) * [eps(tau)/ell(tau)]
    and the Lagrange polynomial fits the normalized bracket, so the
    per-step weight keeps ell *inside* the integral:

        C[k, j] = mu(ts[k+1]) * \int l_j(x(rho)) ell(t(rho)) drho,
        nu[k, j] = 1 / ell(ts[k - j])   (the history normalization vector).

    The step-time weight on history entry j is ``C[k, j] * nu[k, j]`` -- the
    executor multiplies the two, so ``nu`` is a genuine per-step coefficient
    leaf that must survive padding/stacking/joining/sharding like any other.

    Returns (psi, C, nu), each with the AB layout of :func:`ab_coefficients`
    (warmup rows lower-order, zero-padded -- nu rows too, so padded history
    slots carry zero weight).
    """
    if basis not in ("t", "rho", "lambda"):
        raise ValueError(f"basis must be 't', 'rho' or 'lambda', got {basis!r}")
    ts = np.asarray(ts, dtype=np.float64)
    n = len(ts) - 1
    mu = np.asarray(sde.mu(ts), dtype=np.float64)
    rho = np.asarray(sde.rho(ts), dtype=np.float64)
    ell = eps_norm_profile(sde, ts, data_var)

    psi = mu[1:] / mu[:-1]
    C = np.zeros((n, order + 1), dtype=np.float64)
    nu = np.zeros((n, order + 1), dtype=np.float64)
    for k in range(n):
        r_eff = min(order, k)
        hist_idx = np.array([k - j for j in range(r_eff + 1)])
        q_rho, q_w = _gauss_legendre(rho[k], rho[k + 1])
        q_t = np.asarray(sde.t_of_rho(q_rho), dtype=np.float64)
        q_ell = eps_norm_profile(sde, q_t, data_var)
        if basis == "rho":
            q_x, nodes = q_rho, rho[hist_idx]
        elif basis == "lambda":
            q_x, nodes = -np.log(q_rho), -np.log(rho[hist_idx])
        else:
            q_x, nodes = q_t, ts[hist_idx]
        for j in range(r_eff + 1):
            C[k, j] = mu[k + 1] * np.sum(
                q_w * q_ell * _lagrange_basis(nodes, j, q_x))
            nu[k, j] = 1.0 / ell[hist_idx[j]]
    return psi, C, nu


def ddim_coefficients_vp(sde, ts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Closed-form Prop. 2 coefficients for VPSDE (deterministic DDIM).

        x' = sqrt(ab'/ab) x + [sqrt(1-ab') - sqrt(ab'/ab) sqrt(1-ab)] eps
    """
    ts = np.asarray(ts, dtype=np.float64)
    ab = np.asarray(sde.alpha_bar(ts), dtype=np.float64)
    psi = np.sqrt(ab[1:] / ab[:-1])
    C = (np.sqrt(1.0 - ab[1:]) - psi * np.sqrt(1.0 - ab[:-1]))[:, None]
    return psi, C


def naive_ei_coefficients(sde: SDE, ts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Ingredient-1-only EI (paper Eq. 8): score parameterization s_theta with
    the *frozen* L_t^{-T} taken at the step start. Used to reproduce Fig. 3a
    (naive EI is WORSE than Euler). Returned as eps-coefficients:

        C_k = [\\int_{t_k}^{t_{k+1}} 1/2 Psi(t_{k+1}, tau) g(tau)^2 dtau] / sigma(t_k)
            = mu(t_{k+1}) [\\int sigma(tau(rho)) drho] / sigma(t_k)
    """
    ts = np.asarray(ts, dtype=np.float64)
    n = len(ts) - 1
    mu = np.asarray(sde.mu(ts), dtype=np.float64)
    sig = np.asarray(sde.sigma(ts), dtype=np.float64)
    rho = np.asarray(sde.rho(ts), dtype=np.float64)
    psi = mu[1:] / mu[:-1]
    C = np.zeros((n, 1), dtype=np.float64)
    for k in range(n):
        q_rho, q_w = _gauss_legendre(rho[k], rho[k + 1])
        q_t = np.asarray(sde.t_of_rho(q_rho), dtype=np.float64)
        integral = mu[k + 1] * np.sum(q_w * np.asarray(sde.sigma(q_t), dtype=np.float64))
        C[k, 0] = integral / sig[k]
    return psi, C


# Classical Adams-Bashforth weights on a *uniform* grid, used by (i)PNDM
# (paper Eqs. 36, 38-40). AB_WEIGHTS[r][j] multiplies eps_{k-j}.
AB_WEIGHTS = {
    0: np.array([1.0]),
    1: np.array([3.0, -1.0]) / 2.0,
    2: np.array([23.0, -16.0, 5.0]) / 12.0,
    3: np.array([55.0, -59.0, 37.0, -9.0]) / 24.0,
}
