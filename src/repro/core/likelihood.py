r"""Data log-likelihood via the transformed PF-ODE (paper App. B Q1).

In the DEIS y-coordinates (Prop. 3) the PF-ODE is ``dy/drho = eps_hat(y, rho)``,
so by the instantaneous change-of-variables formula

    d log p(y_rho) / drho = -div_y eps_hat(y, rho),

and with x = mu(t) y the data NLL is

    log p0(x_0) = log pi_y(y_T) - \int_{rho_0}^{rho_T} div eps_hat drho - D log mu(t0->) ...

We integrate forward in rho (t0 -> T) with the rhoRK integrators, which is the
paper's "NLL with 3rd-order Kutta converges by ~36 NFE, ~4x faster than RK45"
claim (validated in benchmarks/nll_bench.py). Divergence is exact (jacfwd
trace) for small D and Hutchinson-estimated otherwise.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .sde import SDE
from .plan import _TABLEAUS, _f64


def _divergence_exact(fn, y):
    """trace of d fn / d y for a single flat vector y."""
    jac = jax.jacfwd(fn)(y)
    return jnp.trace(jac)


def _divergence_hutchinson(fn, y, key, n_probes: int = 8):
    def one(k):
        v = jax.random.rademacher(k, y.shape, jnp.float32).astype(y.dtype)
        _, jvp_v = jax.jvp(fn, (y,), (v,))
        return jnp.sum(jvp_v * v)
    keys = jax.random.split(key, n_probes)
    return jnp.mean(jax.vmap(one)(keys))


def nll_bits_per_dim(sde: SDE, eps_fn: Callable, x0: jax.Array, n_steps: int = 12,
                     method: str = "kutta3", exact_div: bool = True,
                     key=None, n_probes: int = 8) -> jax.Array:
    """NLL of a batch of flat data vectors x0 (B, D) in bits/dim.

    Integrates y and logp jointly from t0 to T with a fixed-grid rhoRK method
    on a uniform-in-rho grid (adaptive solvers waste NFE at low budgets --
    paper App. B Q2).
    """
    d = x0.shape[-1]
    rho_lo = float(sde.rho(sde.t0))
    rho_hi = float(sde.rho(sde.T))
    # geometric (uniform in log-rho) grid: the divergence integrand
    # concentrates at small rho, where a uniform-in-rho grid undersamples
    rhos = np.exp(np.linspace(np.log(rho_lo), np.log(rho_hi), n_steps + 1))
    ts = _f64(sde.t_of_rho(rhos))
    mus = _f64(sde.mu(ts))
    c, a, b = _TABLEAUS[method]
    s = len(c)
    stage_rho = rhos[:-1, None] + c[None, :] * np.diff(rhos)[:, None]
    stage_t = _f64(sde.t_of_rho(stage_rho))
    stage_mu = _f64(sde.mu(stage_t))
    h = np.diff(rhos)

    a_mat = np.zeros((s, s))
    for i, row in enumerate(a):
        a_mat[i, : len(row)] = row

    def eps_hat(y, k, i):
        return eps_fn(stage_mu[k, i] * y, jnp.asarray(stage_t[k, i], y.dtype))

    def single(x0_i, key_i):
        y = x0_i / mus[0]
        logp_delta = jnp.zeros(())
        for k in range(n_steps):  # static unroll: n_steps is small
            ks, divs = [], []
            for i in range(s):
                y_i = y
                for j in range(i):
                    y_i = y_i + h[k] * a_mat[i, j] * ks[j]
                fn = lambda yy, k=k, i=i: eps_hat(yy, k, i)
                ks.append(fn(y_i))
                if exact_div:
                    divs.append(_divergence_exact(fn, y_i))
                else:
                    key_i, sub = jax.random.split(key_i)
                    divs.append(_divergence_hutchinson(fn, y_i, sub, n_probes))
            y = y + h[k] * sum(float(b[i]) * ks[i] for i in range(s))
            logp_delta = logp_delta - h[k] * sum(float(b[i]) * divs[i] for i in range(s))
        # prior: x_T ~ N(0, (mu_T^2 + sigma_T^2) I) => y_T ~ N(0, (1 + rho_T^2) I)
        var_y = 1.0 + rho_hi ** 2
        logp_prior = -0.5 * jnp.sum(y ** 2) / var_y - 0.5 * d * jnp.log(2 * jnp.pi * var_y)
        # log p_x(x0) = log p_y(y0) - D log mu(t0); we computed logp_y(y_t0) via flow
        logp_y0 = logp_prior - logp_delta
        logp_x0 = logp_y0 - d * jnp.log(mus[0])
        return -(logp_x0) / d / jnp.log(2.0)

    if key is None:
        key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, x0.shape[0])
    return jax.vmap(single)(x0, keys)
