r"""Matrix-coefficient DEIS: critically-damped Langevin diffusion (CLD).

The paper (Sec. 2): "Our approach is applicable to any DMs, including ... the
critically-damped Langevin diffusion (CLD) (Dockhorn et al., 2021) where
these coefficients are indeed non-diagonal matrices." This module makes that
claim concrete: the augmented state per data dimension is z = (x, v) and

    dz = beta(t) A z dt + G_t dw,
    A  = [[0, 1/M], [-1, -Gamma/M]],   G_t = diag(0, sqrt(2*Gamma*beta)),

with critical damping M = Gamma^2 / 4. Everything the scalar engine uses
generalizes:

  * transition matrix  Psi(t, s) = expm(A * (B(t) - B(s))),  B = \int beta —
    closed form under critical damping (double eigenvalue -2/Gamma):
        expm(A u) = e^{lam u} (I + (A - lam I) u).
  * marginal covariance Sigma(t): Lyapunov ODE dSigma/dB = A S + S A^T + N,
    N = [[0,0],[0, 2 Gamma]], integrated ONCE on the host in float64 (the
    paper: "even if analytic formulas are not available, one can use high
    accuracy solvers to obtain these coefficients").
  * eps-parameterization with the 2x2 Cholesky L_t of Sigma(t):
    score = -L_t^{-T} eps.
  * tAB-DEIS coefficients C_ij become 2x2 MATRICES:
        C_ij = \int Psi(t', tau) (beta/2) N L_tau^{-T} l_j(tau) dtau
    via the same Gauss-Legendre quadrature.

Validated in tests/test_matrix_cld.py: r-order matrix-AB converges at order
r+1 against a fine-grid reference on an exactly-scored Gaussian problem.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .coeffs import _gauss_legendre, _lagrange_basis


@dataclasses.dataclass
class CLD:
    """Critically-damped Langevin forward SDE (per data dimension)."""

    gamma: float = 2.0           # friction Gamma; M = Gamma^2/4
    beta_min: float = 0.1
    beta_max: float = 8.0
    v_init_frac: float = 0.04    # gamma_0: initial v variance = gamma_0 * M
    T: float = 1.0
    t0: float = 1e-3
    _n_lyap: int = 4000

    def __post_init__(self):
        g = self.gamma
        m_inv = 4.0 / g ** 2
        self.A = np.array([[0.0, m_inv], [-1.0, -g * m_inv]])
        self.N = np.array([[0.0, 0.0], [0.0, 2.0 * g]])
        self.lam = -2.0 / g
        self._precompute_sigma()

    # ---- time scalings -----------------------------------------------------
    def beta(self, t):
        return self.beta_min + t * (self.beta_max - self.beta_min)

    def B(self, t):
        t = np.asarray(t, dtype=np.float64)
        return self.beta_min * t + 0.5 * t ** 2 * (self.beta_max - self.beta_min)

    # ---- transition matrix --------------------------------------------------
    def psi(self, t, s) -> np.ndarray:
        """expm(A (B(t)-B(s))) in closed form (critical damping)."""
        u = float(self.B(t) - self.B(s))
        lam = self.lam
        return np.exp(lam * u) * (np.eye(2) + (self.A - lam * np.eye(2)) * u)

    # ---- marginal covariance -------------------------------------------------
    def _precompute_sigma(self):
        """Integrate the Lyapunov ODE on a fine B-grid (host, float64)."""
        b_hi = float(self.B(self.T))
        bs = np.linspace(0.0, b_hi, self._n_lyap + 1)
        m = self.gamma ** 2 / 4.0
        sig = np.zeros((2, 2))
        sig[1, 1] = self.v_init_frac * m
        a, n = self.A, self.N
        sigs = [sig.copy()]
        for i in range(self._n_lyap):
            h = bs[i + 1] - bs[i]

            def f(s):
                return a @ s + s @ a.T + n

            k1 = f(sig)
            k2 = f(sig + 0.5 * h * k1)
            k3 = f(sig + 0.5 * h * k2)
            k4 = f(sig + h * k3)
            sig = sig + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
            sigs.append(sig.copy())
        self._b_grid = bs
        self._sigma_grid = np.stack(sigs)

    def sigma(self, t) -> np.ndarray:
        """Sigma(t) for the conditional p(z_t | x_0 fixed, v_0 ~ N(0, g0 M))."""
        b = float(self.B(t))
        return np.stack([np.interp(b, self._b_grid, self._sigma_grid[:, i, j])
                         for i in range(2) for j in range(2)]).reshape(2, 2)

    def chol(self, t) -> np.ndarray:
        s = self.sigma(t)
        # regularize the (near-singular at t->0) xx entry
        return np.linalg.cholesky(s + 1e-12 * np.eye(2))

    def equilibrium_cov(self) -> np.ndarray:
        """Sigma_infty = diag(1, M) for CLD's stationary unit scaling."""
        m = self.gamma ** 2 / 4.0
        return np.diag([1.0, m])


def cld_ab_coefficients(cld: CLD, ts: np.ndarray, order: int):
    """Matrix tAB-DEIS coefficients.

    Returns psi: (N, 2, 2) and C: (N, order+1, 2, 2) with the update

        z_{k+1} = psi[k] @ z_k + sum_j C[k, j] @ eps(z_{k-j}, t_{k-j}).
    """
    ts = np.asarray(ts, dtype=np.float64)
    n = len(ts) - 1
    psi = np.stack([cld.psi(ts[k + 1], ts[k]) for k in range(n)])
    C = np.zeros((n, order + 1, 2, 2))
    for k in range(n):
        r_eff = min(order, k)
        nodes_t = np.array([ts[k - j] for j in range(r_eff + 1)])
        q_t, q_w = _gauss_legendre(ts[k], ts[k + 1], 64)
        for j in range(r_eff + 1):
            lj = _lagrange_basis(nodes_t, j, q_t)
            acc = np.zeros((2, 2))
            for qi in range(len(q_t)):
                tau = float(q_t[qi])
                l_inv_t = np.linalg.inv(cld.chol(tau)).T
                integrand = cld.psi(ts[k + 1], tau) @ (
                    0.5 * cld.beta(tau) * cld.N) @ l_inv_t
                acc += q_w[qi] * lj[qi] * integrand
            C[k, j] = acc
    return psi, C


class CLDGaussianOracle:
    """Exact eps(z, t) for 1-D Gaussian data x0 ~ N(mean, var) under CLD."""

    def __init__(self, cld: CLD, mean: float, var: float):
        self.cld, self.mean, self.var = cld, mean, var

    def _moments(self, t):
        psi0 = self.cld.psi(t, 0.0)
        m_t = psi0 @ np.array([self.mean, 0.0])
        data_cov = np.array([[self.var, 0.0], [0.0, 0.0]])
        s_t = psi0 @ data_cov @ psi0.T + self.cld.sigma(t)
        return m_t, s_t

    def eps_fn(self):
        cld = self.cld

        def eps(z, t):
            # z: (..., 2); t static per call from host-side solver
            t_f = float(t)
            m_t, s_t = self._moments(t_f)
            score = -(z - jnp.asarray(m_t)) @ jnp.asarray(
                np.linalg.inv(s_t + 1e-12 * np.eye(2)).T)
            l_t = cld.chol(t_f)
            return -score @ jnp.asarray(l_t)   # eps = -L^T score

        return eps


def cld_sample(cld: CLD, ts, order: int, eps_fn, z_T):
    """Host-driven matrix tAB-DEIS sampler (analysis tool; times static)."""
    psi, C = cld_ab_coefficients(cld, np.asarray(ts), order)
    n = len(ts) - 1
    hist: list = []
    z = z_T
    for k in range(n):
        e = eps_fn(z, float(ts[k]))
        hist = [e] + hist[: order]
        z = z @ jnp.asarray(psi[k]).T
        for j in range(min(order, k) + 1):
            z = z + hist[j] @ jnp.asarray(C[k, j]).T
    return z


def cld_reference(cld: CLD, eps_fn, z_T, n_steps: int = 4000):
    """Fine-grid RK4 on the CLD probability-flow ODE (reference solution).

    dz/dt = beta [A z - 0.5 N score] = beta A z + 0.5 beta N L^{-T} eps
    """
    ts = np.linspace(cld.T, cld.t0, n_steps + 1)
    z = z_T

    def f(z, t):
        e = eps_fn(z, t)
        l_inv_t = np.linalg.inv(cld.chol(t)).T
        drift_lin = z @ jnp.asarray(cld.beta(t) * cld.A).T
        drift_nl = e @ jnp.asarray(0.5 * cld.beta(t) * cld.N @ l_inv_t).T
        return drift_lin + drift_nl

    for k in range(n_steps):
        h = ts[k + 1] - ts[k]
        k1 = f(z, ts[k])
        k2 = f(z + 0.5 * h * k1, ts[k] + 0.5 * h)
        k3 = f(z + 0.5 * h * k2, ts[k] + 0.5 * h)
        k4 = f(z + h * k3, ts[k + 1])
        z = z + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
    return z
