r"""SolverPlan: immutable per-step coefficient pytrees for every DEIS-family
solver (paper Secs. 3-4, App. H.2).

The paper's whole solver family shares one semilinear structure: coefficients
are precomputed once on the host (float64 numpy) and then applied in a fixed
loop of cheap affine updates around the eps-network calls. A ``SolverPlan``
captures exactly that split:

  * dynamic leaves (jit/vmap/pjit-traced): ``ts`` and a ``coeffs`` dict of
    per-step arrays, and
  * static metadata (part of the pytree treedef, hence the jit cache key):
    the step ``method`` tag, ``stochastic``/``fused`` flags and the NFE count.

Three step methods cover all twenty ``SOLVER_NAMES``:

  ``ab``    x' = psi[k] x + C[k] @ eps_hist (+ s[k] xi for stochastic plans).
            Covers tAB/rhoAB-DEIS (any order), deterministic & stochastic
            DDIM, naive EI, Euler on the x-space PF-ODE (psi = 1 + dt f), and
            Euler-Maruyama on the lambda-SDE -- they are all affine in
            (x, eps history, noise) once coefficients are precomputed.
            iPNDM folds its uniform-grid AB weights into C (C[k,j] =
            C0[k] * W[k,j]) and lands here too.
  ``rk``    rhoRK-DEIS on dy/drho = eps_hat (Prop. 3) with a *per-step*
            Butcher tableau A[k]; DPM-Solver-2's geometric-mean stage is just
            a per-step a21, so it needs no special case.
  ``pndm``  original PNDM: 3 pseudo-RK4 warmup steps (precomputed DDIM
            transfer ratios) + AB4 tail folded into C like iPNDM.

Plans are consumed by :mod:`repro.core.sampler` (``sample`` / ``step``).
Builders (``plan_ab``, ``plan_rk``, ``plan_ddim``, ``plan_euler``,
``plan_em``, ``plan_ipndm``, ``plan_pndm``) subsume the precompute that used
to live in the solver-class ``__init__``s; ``make_plan`` is the name-based
factory mirroring ``make_solver``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import coeffs as C
from .sde import SDE, VPSDE


def _f64(x):
    return np.asarray(x, dtype=np.float64)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SolverPlan:
    """Immutable pytree of precomputed per-step solver coefficients.

    ``coeffs``/``ts`` are dynamic leaves; ``method``, ``stochastic``,
    ``fused`` and ``nfe`` are static (they select the executor trace).
    Two plans with equal :meth:`signature` share one jitted executor.
    """

    coeffs: dict = dataclasses.field(metadata=dict(static=False))
    ts: jax.Array = dataclasses.field(metadata=dict(static=False))
    method: str = dataclasses.field(metadata=dict(static=True))
    stochastic: bool = dataclasses.field(default=False, metadata=dict(static=True))
    fused: bool = dataclasses.field(default=False, metadata=dict(static=True))
    nfe: int = dataclasses.field(default=0, metadata=dict(static=True))
    stacked: bool = dataclasses.field(default=False, metadata=dict(static=True))
    # True when the plan carries an embedded lower-order companion ("E" for
    # the ab/pndm families, "b_err" for rk): step() then maintains a per-row
    # local-error estimate in SamplerState.err. Static because it changes the
    # executor trace (the estimate is extra compute + an extra output leaf).
    error_estimate: bool = dataclasses.field(default=False,
                                             metadata=dict(static=True))

    @property
    def n_steps(self) -> int:
        """Solver steps on this plan's grid (``len(ts) - 1``; includes any
        inert steps appended by :func:`pad_plan` -- ``nfe`` does not)."""
        return self.ts.shape[-1] - 1

    @property
    def batch(self) -> int:
        """Leading request axis of a stacked plan (1 for unstacked plans)."""
        return self.ts.shape[0] if self.stacked else 1

    @property
    def history_len(self) -> int:
        """Rows of eps history carried in ``SamplerState.hist``."""
        if self.method == "ab":
            return self.coeffs["C"].shape[-1]
        if self.method == "pndm":
            return 4
        return 0  # rk: stage evals live inside one step

    @property
    def signature(self) -> tuple:
        """Trace identity: plans with equal signatures (and equal batch/shape
        of the sampled state) reuse one compiled executor."""
        leaves = tuple(sorted((k, tuple(v.shape), str(v.dtype))
                              for k, v in self.coeffs.items()))
        return (self.method, self.stochastic, self.fused, self.stacked,
                self.error_estimate, tuple(self.ts.shape), leaves)

    @property
    def family(self) -> tuple:
        """Signature with the step-count axis wildcarded (unstacked plans).

        Two plans of the same family differ only in how many solver steps
        they take (e.g. ddim@4 vs ddim@8, or tab3@6 vs ipndm3@10): padding
        the shorter one with :func:`pad_plan` makes their signatures equal,
        so they can stack into one ragged serving group. The serving engine
        buckets pending requests by ``(plan.family, seq_len)``.
        """
        if self.stacked:
            raise ValueError("family is defined for unstacked plans (it is "
                             "the admission-bucketing key, applied before "
                             "stacking)")

        n = self.n_steps

        def wild(name, shape):
            if _leaf_role(name, shape, n) != "static":
                return ("*",) + shape[1:]
            return shape

        leaves = tuple(sorted((k, wild(k, tuple(v.shape)), str(v.dtype))
                              for k, v in self.coeffs.items()))
        return (self.method, self.stochastic, self.fused,
                self.error_estimate, ("*",), leaves)

    def astype(self, dtype) -> "SolverPlan":
        """Cast floating leaves to ``dtype`` (no-op fast path when already
        there -- ``step()`` calls this every step). Static metadata, and
        therefore the signature's method/flags part, is unchanged."""
        dtype = jnp.dtype(dtype)
        needs = lambda a: jnp.issubdtype(a.dtype, jnp.floating) and a.dtype != dtype
        if not needs(self.ts) and not any(needs(v) for v in self.coeffs.values()):
            return self  # fast path: step() calls this every step
        cast = lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a
        return dataclasses.replace(
            self, coeffs={k: cast(v) for k, v in self.coeffs.items()},
            ts=cast(self.ts))


def stack_plans(plans) -> SolverPlan:
    """Stack same-signature plans along a new leading *request* axis.

    This is what lets a serving batch mix solver *names*: any plans whose
    :attr:`SolverPlan.signature` matches (same step method, stochasticity and
    coefficient shapes -- e.g. ddim / euler / naive_ei at one NFE, or
    em / ddim_eta) become ONE stacked plan whose coefficient leaves carry a
    leading ``(R, ...)`` axis. The executor applies row ``i`` of the stack to
    row ``i`` of a batched ``SamplerState``, so one compiled ``step``/
    ``sample`` serves a heterogeneous request group.

    A stacked plan requires a batched state: ``x`` is ``(R, *inner)``, and
    stochastic plans take per-request PRNG keys of shape ``(R, 2)``.

    Plans may carry *different* true NFE counts (ragged groups built by
    :func:`pad_plan` -- e.g. ddim@4 stacked with ddim@8): the stacked plan's
    static ``nfe`` is the maximum, so per-request accounting must be tracked
    by the caller from each member plan (the serving engine keeps it per
    row).
    """
    plans = list(plans)
    if not plans:
        raise ValueError("stack_plans requires at least one plan")
    base = plans[0]
    if base.stacked:
        raise ValueError("cannot re-stack an already stacked plan")
    for p in plans[1:]:
        if p.signature != base.signature:
            raise ValueError(
                f"cannot stack plans with different signatures:\n  {base.signature}"
                f"\n  {p.signature}")
    coeffs = {k: jnp.stack([p.coeffs[k] for p in plans])
              for k in base.coeffs}
    ts = jnp.stack([p.ts for p in plans])
    return dataclasses.replace(base, coeffs=coeffs, ts=ts, stacked=True,
                               nfe=max(p.nfe for p in plans))


# Per-step coefficient leaves (leading axis == n_steps) and per-knot leaves
# (leading axis == n_steps + 1, like ``ts``). This registry is what
# ragged-NFE serving relies on: `pad_plan` extends exactly these axes,
# `SolverPlan.family` wildcards them and `inert_row` zeroes the weight-like
# ones, so the three can never disagree about which leaves carry the step
# dimension.
_PER_STEP_COEFFS = frozenset({"psi", "C", "E", "s", "nu", "h", "stage_t",
                              "stage_mu", "A"})
_PER_KNOT_COEFFS = frozenset({"mu"})
# time-like per-step leaves are edge-replicated (not zero-padded) so padded
# steps never evaluate the eps network at an out-of-domain t
_TIME_LIKE = frozenset({"stage_t"})
# Step-count-INDEPENDENT leaves whose leading axis could *coincidentally*
# equal n_steps (an rk "b" of 3 stages on a 3-step grid; pndm warm-up arrays
# on tiny grids). They must never be padded/wildcarded/zeroed, so they are
# pinned static by name and the shape heuristic below never sees them.
_STATIC_COEFFS = frozenset({"b", "b_err", "warm_ratio_m", "warm_coef_m",
                            "warm_ratio_n", "warm_coef_n", "warm_t_mid"})


def _leaf_role(name: str, shape: tuple, n_steps: int) -> str:
    """Classify a coefficient leaf as 'step' / 'knot' / 'time' / 'static'.

    Registered names win; a NOVEL key (a solver family this module has never
    heard of -- e.g. a future per-step normalization or conditioning vector)
    falls through to a shape heuristic: leading axis == n_steps is treated as
    a per-step weight (zero-padded, wildcarded, zeroed by ``inert_row``),
    leading axis == n_steps + 1 as per-knot (edge-replicated, wildcarded),
    anything else as static. This is what lets the splice primitives --
    ``pad_plan`` / ``stack_plans`` / ``take_rows`` / ``join_rows`` /
    ``inert_row`` -- carry arbitrary coefficient dicts through ragged
    serving without a per-family code change."""
    if name in _TIME_LIKE:
        return "time"
    if name in _PER_KNOT_COEFFS:
        return "knot"
    if name in _PER_STEP_COEFFS:
        return "step"
    if name in _STATIC_COEFFS:
        return "static"
    if len(shape) and shape[0] == n_steps:
        return "step"
    if len(shape) and shape[0] == n_steps + 1:
        return "knot"
    return "static"


def pad_plan(plan: SolverPlan, n_steps: int) -> SolverPlan:
    """Extend an unstacked plan to ``n_steps`` solver steps by padding.

    Padded steps are inert for practical purposes: weight-like coefficients
    (psi / C / s / h / A / stage_mu) are zero-filled and time/knot-like
    leaves (ts / mu / stage_t) are edge-replicated, so stepping past the true
    grid keeps every array finite and every eps-network call in-domain. The
    first ``plan.n_steps`` steps are the ORIGINAL arrays bit-for-bit, which
    is what makes ragged serving groups per-request reproducible: a request
    solved inside a padded stack takes exactly the steps its own plan
    prescribes, and its row is captured when its true step count is reached.

    Static metadata (``nfe`` in particular) is unchanged -- padding adds no
    network evaluations that anyone should account for. Two plans of one
    :attr:`SolverPlan.family` padded to the same ``n_steps`` have equal
    signatures and therefore stack via :func:`stack_plans`.
    """
    if plan.stacked:
        raise ValueError("pad_plan operates on unstacked plans (pad, then stack)")
    n = plan.n_steps
    if n_steps == n:
        return plan
    if n_steps < n:
        raise ValueError(f"cannot pad a {n}-step plan down to {n_steps} steps")
    pad = n_steps - n

    def edge(v):
        return jnp.concatenate([v, jnp.repeat(v[-1:], pad, axis=0)])

    def zeros(v):
        return jnp.concatenate(
            [v, jnp.zeros((pad,) + v.shape[1:], v.dtype)])

    coeffs = {}
    for name, v in plan.coeffs.items():
        role = _leaf_role(name, tuple(v.shape), n)
        if role in ("knot", "time"):
            coeffs[name] = edge(v)
        elif role == "step":
            coeffs[name] = zeros(v)
        else:
            coeffs[name] = v
    return dataclasses.replace(plan, coeffs=coeffs, ts=edge(plan.ts))


def take_rows(plan: SolverPlan, rows, shardings=None) -> SolverPlan:
    """Row-gather a stacked plan: keep requests ``rows`` (in that order).

    ``rows`` is a host-side index sequence into the leading request axis.
    Every coefficient leaf and ``ts`` is gathered on axis 0, so the surviving
    rows' per-step coefficients are bit-identical to what they were in the
    larger stack -- this is the plan half of mid-flight group compaction
    (the state half is :func:`repro.core.sampler.take_state_rows`). The
    result is still a stacked plan (even for a single surviving row) with the
    same signature family at the new, smaller batch.

    ``shardings`` (a plan-shaped tree of ``jax.sharding.Sharding``, e.g. from
    :func:`repro.sharding.rules.plan_specs` at the NEW batch size) makes the
    gather *sharding-preserving*: the gathered leaves are committed to those
    placements, so feeding the compacted plan to an AOT-compiled sharded
    executor never triggers a resharding recompile mid-flight.
    """
    if not plan.stacked:
        raise ValueError("take_rows requires a stacked plan")
    # repro: allow[RL001] rows is a host-side index list by contract (scheduler bookkeeping)
    idx = np.asarray(rows, dtype=np.int32)
    if idx.ndim != 1 or idx.size == 0:
        raise ValueError(f"rows must be a non-empty 1-D index sequence, got "
                         f"shape {idx.shape}")
    out = dataclasses.replace(
        plan, coeffs={k: v[idx] for k, v in plan.coeffs.items()},
        ts=plan.ts[idx])
    if shardings is not None:
        out = jax.device_put(out, shardings)
    return out


def _rowless_signature(plan: SolverPlan) -> tuple:
    """Trace identity of a stacked plan's ROWS (leading request axis
    stripped): two stacks whose rowless signatures match may be spliced
    into one group without changing the executor trace family."""
    leaves = tuple(sorted((k, tuple(v.shape[1:]), str(v.dtype))
                          for k, v in plan.coeffs.items()))
    return (plan.method, plan.stochastic, plan.fused, plan.error_estimate,
            tuple(plan.ts.shape[1:]), leaves)


def join_rows(plan: SolverPlan, new_plans, shardings=None) -> SolverPlan:
    """Splice joiner rows onto a stacked plan's request axis.

    ``new_plans`` are UNSTACKED same-family plans; each is padded to the
    stacked plan's step horizon with :func:`pad_plan` (inert zero/edge
    padding; a joiner longer than the horizon is rejected -- it must wait
    for a fresh group rather than force a grid extension, which would
    change the group's signature and recompile its executor). The joined
    plan's leading rows are the ORIGINAL stack bit-for-bit (concatenation
    never touches them) and the appended rows are the padded joiners
    bit-for-bit, so ``take_rows(join_rows(p, new), range(p.batch))``
    round-trips to ``p`` exactly. The signature keeps the same family at
    the grown batch, so the serving executor cache is looked up, never
    re-traced, per (signature, batch, seq_len).

    This is the plan half of join-at-compaction (continuous admission);
    the state half is :func:`repro.core.sampler.join_state_rows`. Joined
    rows start at step 0 while veterans continue at their own counts --
    the executor's per-row ``k`` vector keeps both correct.

    ``shardings`` (plan-shaped tree of shardings at the NEW batch) commits
    the spliced leaves, mirroring :func:`take_rows`.
    """
    if not plan.stacked:
        raise ValueError("join_rows splices rows onto a stacked plan")
    new_plans = list(new_plans)
    if not new_plans:
        raise ValueError("join_rows requires at least one joiner plan")
    padded = []
    for p in new_plans:
        if p.stacked:
            raise ValueError("joiner plans must be unstacked (one per row)")
        if p.n_steps > plan.n_steps:
            raise ValueError(
                f"cannot join a {p.n_steps}-step plan into a stack with a "
                f"{plan.n_steps}-step horizon: extending the grid would "
                "change the stack's signature (form a fresh group instead)")
        padded.append(pad_plan(p, plan.n_steps))
    add = stack_plans(padded)
    if _rowless_signature(add) != _rowless_signature(plan):
        raise ValueError(
            f"joiner rows are not of the stack's family:\n  "
            f"{_rowless_signature(plan)}\n  {_rowless_signature(add)}")
    out = dataclasses.replace(
        plan,
        coeffs={k: jnp.concatenate([plan.coeffs[k], add.coeffs[k]])
                for k in plan.coeffs},
        ts=jnp.concatenate([plan.ts, add.ts]),
        nfe=max(plan.nfe, add.nfe))
    if shardings is not None:
        out = jax.device_put(out, shardings)
    return out


def inert_row(plan: SolverPlan) -> SolverPlan:
    """A same-signature plan whose every step is inert: structural filler.

    Weight-like per-step coefficients (psi / C / s / h / A / stage_mu) are
    zeroed, so the row's iterate update is the zero map and its noise scale
    is zero; time-like and knot-like leaves (ts / mu / stage_t, and the
    method-specific extras like PNDM's warm-up ratios) are copied so every
    eps-network call on the row stays in-domain and finite. Sharded serving
    uses this to round group sizes up to a multiple of the mesh's data-axis
    size: pad rows stack with real requests (equal signature), place evenly,
    compute garbage nobody reads, and retire for free.
    """
    if plan.stacked:
        raise ValueError("inert_row operates on unstacked plans (build the "
                         "filler, then stack with the real rows)")
    coeffs = {}
    for name, v in plan.coeffs.items():
        if _leaf_role(name, tuple(v.shape), plan.n_steps) == "step":
            coeffs[name] = jnp.zeros_like(v)
        else:
            coeffs[name] = v
    return dataclasses.replace(plan, coeffs=coeffs, nfe=0)


def _mk(method: str, coeffs: dict, ts: np.ndarray, *, stochastic=False,
        fused=False, nfe: int, error_estimate=False) -> SolverPlan:
    coeffs = {k: jnp.asarray(v) for k, v in coeffs.items()}
    return SolverPlan(coeffs=coeffs, ts=jnp.asarray(_f64(ts)), method=method,
                      stochastic=stochastic, fused=fused, nfe=nfe,
                      error_estimate=error_estimate)


# --------------------------------------------------------------------- AB
def plan_ab(sde: SDE, ts, order: int = 0, basis: str = "t",
            naive_ei: bool = False, fused: bool = False,
            error_estimate: bool = False) -> SolverPlan:
    """tAB/rhoAB-DEIS (Eq. 14); r=0 == deterministic DDIM (Prop. 2).

    ``fused`` routes the multistep combination through the Pallas
    ``deis_step`` kernel (one HBM round-trip instead of r+2).

    ``error_estimate`` adds the embedded order-(r-1) companion weights
    ``E = C_r - C_{r-1}`` (zero-padded to C's width): ``E[k] @ hist`` is the
    difference between this step's update and the one-order-lower update --
    a free local-error proxy from the SAME eps evaluations (the DPM-Solver
    trick). Warmup rows, where both orders coincide, are exactly zero, which
    ``step()`` reads as "no estimate yet". Order 0 has no lower order, so the
    request is ignored there (the plan's ``error_estimate`` stays False and
    such rows never early-exit).
    """
    ts = _f64(ts)
    if naive_ei:
        if order != 0:
            raise ValueError("naive EI is zero-order only")
        psi, Cm = C.naive_ei_coefficients(sde, ts)
    else:
        psi, Cm = C.ab_coefficients(sde, ts, order, basis)
    coeffs = {"psi": psi, "C": Cm}
    has_pair = error_estimate and order >= 1 and not naive_ei
    if has_pair:
        _, C_lo = C.ab_coefficients(sde, ts, order - 1, basis)
        E = np.array(Cm, dtype=np.float64, copy=True)
        E[:, :order] -= C_lo
        coeffs["E"] = E
    return _mk("ab", coeffs, ts, fused=fused, nfe=len(ts) - 1,
               error_estimate=has_pair)


def plan_ddim(sde: VPSDE, ts, eta: float = 0.0) -> SolverPlan:
    """Stochastic DDIM(eta) for VPSDE (Prop. 4, Eq. 34); eta=0 is the
    deterministic DDIM and produces a deterministic plan."""
    if not isinstance(sde, VPSDE):
        raise TypeError("stochastic DDIM is defined for VPSDE")
    ts = _f64(ts)
    ab = _f64(sde.alpha_bar(ts))
    sig2 = (eta ** 2) * (1 - ab[1:]) / (1 - ab[:-1]) * (1 - ab[:-1] / ab[1:])
    sig2 = np.maximum(sig2, 0.0)
    a = np.sqrt(ab[1:] / ab[:-1])
    # x' = a x + b eps + s xi,  b = sqrt(1-ab'-sig2) - a sqrt(1-ab)
    b = np.sqrt(np.maximum(1 - ab[1:] - sig2, 0.0)) - a * np.sqrt(1 - ab[:-1])
    coeffs = {"psi": a, "C": b[:, None]}
    if eta > 0:
        coeffs["s"] = np.sqrt(sig2)
    return _mk("ab", coeffs, ts, stochastic=eta > 0, nfe=len(ts) - 1)


def plan_euler(sde: SDE, ts) -> SolverPlan:
    """Explicit Euler on the x-space PF-ODE (Eq. 7), folded to affine form:
    x' = (1 + dt f) x + (dt * g^2 / (2 sigma)) eps."""
    ts = _f64(ts)
    dt = ts[1:] - ts[:-1]
    psi = 1.0 + dt * _f64(sde.f(ts[:-1]))
    Cm = (dt * 0.5 * _f64(sde.g2(ts[:-1])) / _f64(sde.sigma(ts[:-1])))[:, None]
    return _mk("ab", {"psi": psi, "C": Cm}, ts, nfe=len(ts) - 1)


def plan_em(sde: SDE, ts, lam: float = 1.0) -> SolverPlan:
    """Euler-Maruyama on the lambda-SDE (Eq. 4); lambda=1 = reverse diffusion.
    Affine form with per-step noise scale s = lam g sqrt(-dt)."""
    ts = _f64(ts)
    dt = ts[1:] - ts[:-1]
    psi = 1.0 + dt * _f64(sde.f(ts[:-1]))
    coef = 0.5 * (1 + lam ** 2) * _f64(sde.g2(ts[:-1])) / _f64(sde.sigma(ts[:-1]))
    s = lam * np.sqrt(_f64(sde.g2(ts[:-1]))) * np.sqrt(-dt)
    return _mk("ab", {"psi": psi, "C": (dt * coef)[:, None], "s": s}, ts,
               stochastic=True, nfe=len(ts) - 1)


def plan_ipndm(sde: SDE, ts, order: int = 3,
               error_estimate: bool = False) -> SolverPlan:
    """Improved PNDM (App. H.2, Algo 4): classical uniform-grid AB weights
    with lower-order warmup, folded into the AB coefficient matrix.

    ``error_estimate`` folds the classical AB pair the same way:
    ``E[k] = C0[k] * (W[r_eff] - W[r_eff - 1])``, zero at k=0 (no lower
    order to compare against yet)."""
    ts = _f64(ts)
    psi, C0 = C.ab_coefficients(sde, ts, 0, "t")
    n = len(ts) - 1
    Cm = np.zeros((n, order + 1))
    for k in range(n):
        r_eff = min(order, k)
        Cm[k, : r_eff + 1] = C0[k, 0] * C.AB_WEIGHTS[r_eff]
    coeffs = {"psi": psi, "C": Cm}
    has_pair = error_estimate and order >= 1
    if has_pair:
        E = np.zeros((n, order + 1))
        for k in range(1, n):
            r_eff = min(order, k)
            E[k, : r_eff + 1] = C0[k, 0] * C.AB_WEIGHTS[r_eff]
            E[k, : r_eff] -= C0[k, 0] * C.AB_WEIGHTS[r_eff - 1]
        coeffs["E"] = E
    return _mk("ab", coeffs, ts, nfe=n, error_estimate=has_pair)


# --------------------------------------------- next-gen multistep families
def plan_dpm_multistep(sde: SDE, ts, order: int = 2,
                       error_estimate: bool = False) -> SolverPlan:
    """DPM-Solver-2/3 multistep (Lu et al. 2022, arXiv 2206.00927).

    DPM-Solver's multistep variants are Adams-Bashforth extrapolation of the
    eps history in the half-log-SNR coordinate lambda = log(mu/sigma):
    ``drho = -exp(-lambda) dlambda`` turns the DEIS quadrature
    ``mu' * int l_j(lambda(rho)) drho`` into exactly DPM-Solver's
    lambda-Taylor finite-difference updates, so the family reuses the AB
    history machinery wholesale -- an ``ab`` plan with lambda-basis
    coefficients. ``order`` is the overall convergence order (2 or 3; the
    polynomial degree is ``order - 1``).

    ``error_estimate`` adds the embedded DPM-(order-1) companion ``E``
    (lambda-basis lower-degree weights on the same grid): the order-2/3 pair
    the serving early-exit retire path consumes. Warmup rows are exactly
    zero, as for ``plan_ab``."""
    if order not in (2, 3):
        raise ValueError(f"DPM-Solver multistep order must be 2 or 3, got "
                         f"{order}")
    ts = _f64(ts)
    psi, Cm = C.ab_coefficients(sde, ts, order - 1, "lambda")
    coeffs = {"psi": psi, "C": Cm}
    if error_estimate:
        _, C_lo = C.ab_coefficients(sde, ts, order - 2, "lambda")
        E = np.array(Cm, dtype=np.float64, copy=True)
        E[:, : order - 1] -= C_lo
        coeffs["E"] = E
    return _mk("ab", coeffs, ts, nfe=len(ts) - 1,
               error_estimate=error_estimate)


def plan_seeds(sde: SDE, ts, order: int = 1) -> SolverPlan:
    """SEEDS: exponential-integrator solvers for the reverse *SDE* (Gonzalez
    et al. 2023, arXiv 2305.14267).

    The reverse SDE ``dx = [f x + g^2 eps/sigma] dt + g dw`` has the same
    semilinear split as the PF-ODE but a DOUBLED eps drift (g^2/sigma instead
    of g^2/(2 sigma)), so the deterministic part is 2x the lambda-basis AB
    coefficients of degree ``order - 1``. The linear-SDE noise accumulated
    over a step is exact (not Euler-Maruyama): with g^2 = 2 mu^2 rho rho',
    Var = sigma_{k+1}^2 (e^{2h} - 1) for h = lambda_{k+1} - lambda_k > 0,
    recovering the published SEEDS-1 / DPM-SDE-1 transition for order 1.

    Stochastic like ``plan_em``: the plan carries a per-step noise scale
    ``s`` and consumes one per-row PRNG draw per step, so SEEDS rows stack
    with the existing stochastic serving machinery unchanged. No embedded
    pair (the local error is noise-dominated); SEEDS rows never early-exit.
    """
    if order not in (1, 2, 3):
        raise ValueError(f"SEEDS order must be 1, 2 or 3, got {order}")
    ts = _f64(ts)
    psi, Cm = C.ab_coefficients(sde, ts, order - 1, "lambda")
    rho = _f64(sde.rho(ts))
    h = np.log(rho[:-1] / rho[1:])          # lambda increments, > 0
    s = _f64(sde.sigma(ts))[1:] * np.sqrt(np.expm1(2.0 * h))
    return _mk("ab", {"psi": psi, "C": 2.0 * Cm, "s": s}, ts,
               stochastic=True, nfe=len(ts) - 1)


def plan_sndeis(sde: SDE, ts, order: int = 2, basis: str = "t",
                data_var: float = 1.0,
                error_estimate: bool = False) -> SolverPlan:
    """Score-normalized DEIS (arXiv 2311.00157).

    Fits the Lagrange polynomial to the *normalized* integrand
    ``eps(tau)/ell(tau)`` (``ell`` = the RMS eps-magnitude profile, flat
    across t), keeping ``ell`` inside the quadrature. The plan carries the
    per-step normalization vector ``nu[k, j] = 1/ell(ts[k-j])`` as a NEW
    coefficient key: the executor weights history entry j by
    ``C[k, j] * nu[k, j]``. The splice primitives treat coefficient dicts
    generically, so ``nu`` survives padding, stacking, joining, compaction
    and sharding like any registered leaf.

    ``error_estimate`` adds the order-(r-1) companion ``E`` computed with
    the SAME normalization profile (the step applies ``E * nu`` too), so
    SN-DEIS rows retire through serving's early-exit path."""
    ts = _f64(ts)
    psi, Cm, nu = C.sn_ab_coefficients(sde, ts, order, basis, data_var)
    coeffs = {"psi": psi, "C": Cm, "nu": nu}
    has_pair = error_estimate and order >= 1
    if has_pair:
        _, C_lo, _ = C.sn_ab_coefficients(sde, ts, order - 1, basis, data_var)
        E = np.array(Cm, dtype=np.float64, copy=True)
        E[:, :order] -= C_lo
        coeffs["E"] = E
    return _mk("ab", coeffs, ts, nfe=len(ts) - 1, error_estimate=has_pair)


# --------------------------------------------------------------------- RK
_TABLEAUS = {
    "heun": (np.array([0.0, 1.0]),
             [np.array([]), np.array([1.0])],
             np.array([0.5, 0.5])),
    "midpoint": (np.array([0.0, 0.5]),
                 [np.array([]), np.array([0.5])],
                 np.array([0.0, 1.0])),
    "kutta3": (np.array([0.0, 0.5, 1.0]),
               [np.array([]), np.array([0.5]), np.array([-1.0, 2.0])],
               np.array([1.0, 4.0, 1.0]) / 6.0),
    "rk4": (np.array([0.0, 0.5, 0.5, 1.0]),
            [np.array([]), np.array([0.5]), np.array([0.0, 0.5]), np.array([0.0, 0.0, 1.0])],
            np.array([1.0, 2.0, 2.0, 1.0]) / 6.0),
}


# lower-order companion weights per tableau: Euler-from-stage-0 for the
# 2-stage methods, the embedded midpoint rule for the 3/4-stage ones.
# b_err = b - b_lo turns the stage evals already in hand into a local-error
# proxy (err = |mu h (b_err . ks)| in x-space) at zero extra NFE.
_B_LO = {
    "heun": np.array([1.0, 0.0]),
    "midpoint": np.array([1.0, 0.0]),
    "kutta3": np.array([0.0, 1.0, 0.0]),
    "rk4": np.array([0.0, 1.0, 0.0, 0.0]),
}


def plan_rk(sde: SDE, ts, method: str = "heun",
            error_estimate: bool = False) -> SolverPlan:
    """rhoRK-DEIS: explicit RK on dy/drho = eps_hat(y, rho) (Eq. 17, Prop. 3).

    ``method`` in {heun, midpoint, kutta3, rk4, dpm2}; ``dpm2`` is
    DPM-Solver-2 (Lu et al. 2022): midpoint with its stage at the geometric
    mean of (rho_k, rho_{k+1}), expressed here as a per-step a21.

    ``error_estimate`` adds the embedded companion weights ``b_err`` (full
    tableau minus a lower-order rule over the same stages); every step then
    yields a local-error estimate from the stage evals already computed.
    """
    ts = _f64(ts)
    n = len(ts) - 1
    tab = _TABLEAUS["midpoint" if method == "dpm2" else method]
    c, a, b = tab
    s = len(c)
    rho = _f64(sde.rho(ts))
    h = rho[1:] - rho[:-1]  # negative steps
    a_mat = np.zeros((s, s))
    for i, row in enumerate(a):
        a_mat[i, : len(row)] = row
    A = np.broadcast_to(a_mat, (n, s, s)).copy()
    if method == "dpm2":
        lam = -np.log(rho)
        stage_lam = np.stack([lam[:-1], 0.5 * (lam[:-1] + lam[1:])], axis=1)
        stage_rho = np.exp(-stage_lam)
        # stage sits at the geometric mean of (rho_k, rho_{k+1}); advance the
        # stage STATE there with a per-step a21 (exact for the EI transfer)
        A[:, 1, 0] = (stage_rho[:, 1] - rho[:-1]) / h
    else:
        stage_rho = rho[:-1, None] + c[None, :] * h[:, None]
        stage_rho = np.maximum(stage_rho, float(sde.rho(ts[-1])) * (1 - 1e-12))
    stage_t = _f64(sde.t_of_rho(stage_rho))
    coeffs = {"h": h, "mu": _f64(sde.mu(ts)), "stage_t": stage_t,
              "stage_mu": _f64(sde.mu(stage_t)), "A": A, "b": b}
    if error_estimate:
        coeffs["b_err"] = b - _B_LO["midpoint" if method == "dpm2" else method]
    return _mk("rk", coeffs, ts, nfe=n * s, error_estimate=error_estimate)


def plan_scire(sde: SDE, ts, order: int = 2, rd_m: float = 1,
               error_estimate: bool = False) -> SolverPlan:
    """SciRE-Solver: recursive-difference score-integrand RK on the NSR
    coordinate (Li et al. 2023, arXiv 2308.07896).

    SciRE integrates ``dy/drho = eps_hat`` (the NSR rho is the paper's
    score-integrand coordinate) with explicit RK stages whose combination
    weights are scaled by the recursive-difference factor

        phi1(m) = (3/4) * (1 - (-1/3)^m),

    the paper's truncation of the recursive finite-difference expansion of
    the score integrand. ``rd_m = 1`` gives ``phi1 = 1`` -- the classical
    tableau with provable order (the default, so the convergence-order
    harness holds at the nominal order); ``rd_m = float("inf")`` gives the
    paper's asymptotic variant ``phi1 = 3/4`` (formally lower classical
    order, tuned to trained score networks' integrand statistics).

    ``order`` in {2, 3} sets the stage count (2/3 evals per interval --
    serving budgets via :func:`solver_stages`). ``error_estimate`` adds the
    embedded Euler-from-stage-0 companion ``b_err``, so SciRE rows carry a
    local-error estimate from their first step."""
    if order not in (2, 3):
        raise ValueError(f"SciRE order must be 2 or 3, got {order}")
    phi1 = 0.75 * (1.0 - (-1.0 / 3.0) ** rd_m)
    ts = _f64(ts)
    n = len(ts) - 1
    rho = _f64(sde.rho(ts))
    h = rho[1:] - rho[:-1]  # negative steps
    if order == 2:
        c = np.array([0.0, 0.5])
        a_rows = [np.array([]), np.array([0.5])]
        # b2 = 1/(2 r1 phi1) with r1 = 1/2; phi1 = 1 recovers midpoint-Heun
        b = np.array([1.0 - 1.0 / phi1, 1.0 / phi1])
        b_lo = np.array([1.0, 0.0])
    else:
        c = np.array([0.0, 1.0 / 3.0, 2.0 / 3.0])
        a_rows = [np.array([]), np.array([1.0 / 3.0]),
                  np.array([0.0, 2.0 / 3.0])]
        # b3 = 3/(4 phi1); phi1 = 1 recovers Heun's third-order rule
        b = np.array([1.0 - 0.75 / phi1, 0.0, 0.75 / phi1])
        b_lo = np.array([1.0, 0.0, 0.0])
    s = len(c)
    a_mat = np.zeros((s, s))
    for i, row in enumerate(a_rows):
        a_mat[i, : len(row)] = row
    A = np.broadcast_to(a_mat, (n, s, s)).copy()
    stage_rho = rho[:-1, None] + c[None, :] * h[:, None]
    stage_rho = np.maximum(stage_rho, float(sde.rho(ts[-1])) * (1 - 1e-12))
    stage_t = _f64(sde.t_of_rho(stage_rho))
    coeffs = {"h": h, "mu": _f64(sde.mu(ts)), "stage_t": stage_t,
              "stage_mu": _f64(sde.mu(stage_t)), "A": A, "b": b}
    if error_estimate:
        coeffs["b_err"] = b - b_lo
    return _mk("rk", coeffs, ts, nfe=n * s, error_estimate=error_estimate)


# ------------------------------------------------------------------- PNDM
def plan_pndm(sde: SDE, ts, error_estimate: bool = False) -> SolverPlan:
    """Original PNDM (Liu et al. 2022): pseudo-RK4 warmup for the first 3
    steps (4 NFE each, DDIM transfers precomputed as affine ratios) then
    4th-order AB with DDIM transfer. NFE = N + 9.

    ``error_estimate`` equips the AB4 tail with the AB3 companion
    (``E = C0 * (W4 - W3)``); warmup rows carry no estimate (zero rows)."""
    ts = _f64(ts)
    n = len(ts) - 1
    if n < 4:
        raise ValueError("PNDM needs at least 4 steps")
    mu, rho = _f64(sde.mu(ts)), _f64(sde.rho(ts))
    tm = 0.5 * (ts[:-1] + ts[1:])
    mu_mid, rho_mid = _f64(sde.mu(tm)), _f64(sde.rho(tm))
    w = 3  # warmup steps (n >= 4 guaranteed)
    # F_DDIM(x, eps; s->t) = (mu_t/mu_s) x + mu_t (rho_t - rho_s) eps, for
    # the current->midpoint and current->next transfers of each warmup step
    coeffs = {
        "warm_ratio_m": mu_mid[:w] / mu[:w],
        "warm_coef_m": mu_mid[:w] * (rho_mid[:w] - rho[:w]),
        "warm_ratio_n": mu[1:w + 1] / mu[:w],
        "warm_coef_n": mu[1:w + 1] * (rho[1:w + 1] - rho[:w]),
        "warm_t_mid": tm[:w],
    }
    psi, C0 = C.ab_coefficients(sde, ts, 0, "t")
    Cm = np.zeros((n, 4))
    Cm[w:] = C0[w:, :1] * C.AB_WEIGHTS[3][None, :]
    coeffs.update(psi=psi, C=Cm)
    if error_estimate:
        w_err = np.array(C.AB_WEIGHTS[3], dtype=np.float64, copy=True)
        w_err[:3] -= C.AB_WEIGHTS[2]
        E = np.zeros((n, 4))
        E[w:] = C0[w:, :1] * w_err[None, :]
        coeffs["E"] = E
    return _mk("pndm", coeffs, ts, nfe=n + 9, error_estimate=error_estimate)


# ---------------------------------------------------------------- factory
def solver_stages(name: str) -> int:
    """Network evaluations one grid interval costs for solver ``name`` (the
    RK stage count; 1 for every single-eval-per-step family). Lives next to
    the tableau registry so serving's NFE-budget grid sizing can never drift
    from what ``make_plan`` actually builds."""
    n = name.lower()
    if n == "dpm2":
        return len(_TABLEAUS["midpoint"][0])
    if n.startswith("rho_") and n[4:] in _TABLEAUS:
        return len(_TABLEAUS[n[4:]][0])
    if n.startswith("scire"):
        return int(n[5:] or 2)  # SciRE-r runs r stages per interval
    return 1


def make_plan(name: str, sde: SDE, ts, **kw) -> SolverPlan:
    """Name-based factory mirroring ``make_solver``. Names: ddim, tab{0..3},
    rhoab{0..3}, rho_heun, rho_midpoint, rho_kutta3, rho_rk4, dpm2, euler,
    naive_ei, em, ddim_eta (requires explicit ``eta=``), ipndm{1..3}, pndm,
    dpm{2,3}m (DPM-Solver multistep), seeds{1..3} (exponential SDE solvers,
    stochastic), scire{2,3} (recursive-difference RK; ``rd_m=`` selects the
    phi1 variant), sndeis{1..3} (score-normalized DEIS; ``data_var=`` sets
    the normalization profile).

    ``error_estimate=True`` requests embedded local-error estimates and is
    accepted for EVERY name: families with a genuine lower-order pair
    (order>=1 ab/ipndm, rk, pndm) emit companion coefficients; the rest
    ignore the request (their plans keep ``error_estimate=False``), so a
    serving engine can ask uniformly across mixed traffic.
    """
    n = name.lower()
    ee = bool(kw.pop("error_estimate", False))
    if n in ("ddim", "tab0", "rhoab0"):
        return plan_ab(sde, ts, order=0, basis="t", error_estimate=ee, **kw)
    if n.startswith("tab"):
        return plan_ab(sde, ts, order=int(n[3:]), basis="t",
                       error_estimate=ee, **kw)
    if n.startswith("rhoab"):
        return plan_ab(sde, ts, order=int(n[5:]), basis="rho",
                       error_estimate=ee, **kw)
    if n.startswith("rho_"):
        return plan_rk(sde, ts, method=n[4:], error_estimate=ee)
    if n in ("dpm2m", "dpm3m"):
        return plan_dpm_multistep(sde, ts, order=int(n[3]), error_estimate=ee)
    if n == "dpm2":
        return plan_rk(sde, ts, method="dpm2", error_estimate=ee)
    if n.startswith("seeds"):
        return plan_seeds(sde, ts, order=int(n[5:] or 1))
    if n.startswith("scire"):
        return plan_scire(sde, ts, order=int(n[5:] or 2),
                          rd_m=kw.get("rd_m", 1), error_estimate=ee)
    if n.startswith("sndeis"):
        return plan_sndeis(sde, ts, order=int(n[6:] or 2),
                           basis=kw.get("basis", "t"),
                           data_var=kw.get("data_var", 1.0),
                           error_estimate=ee)
    if n == "euler":
        return plan_euler(sde, ts)
    if n == "naive_ei":
        return plan_ab(sde, ts, order=0, naive_ei=True)
    if n == "em":
        return plan_em(sde, ts, lam=kw.get("lam", 1.0))
    if n == "ddim_eta":
        if "eta" not in kw:
            raise TypeError("make_plan('ddim_eta') requires an explicit eta= "
                            "(eta=0 is deterministic DDIM, eta=1 ancestral)")
        return plan_ddim(sde, ts, eta=kw["eta"])
    if n.startswith("ipndm"):
        order = int(n[5:]) if len(n) > 5 else 3
        return plan_ipndm(sde, ts, order=order, error_estimate=ee)
    if n == "pndm":
        return plan_pndm(sde, ts, error_estimate=ee)
    raise ValueError(f"unknown solver {name!r}")


# ------------------------------------------------- plan coefficient cache
# Plans are pure functions of (solver name, SDE parameters, grid, builder
# kwargs): the float64 host precompute (Vandermonde solves, phi integrals,
# quadrature) is deterministic, and the result is an immutable pytree every
# consumer treats as read-only (all splice primitives go through
# dataclasses.replace). Memoizing moves plan construction off the serving
# hot path: an engine's _plan() hits this cache, so admission of a known
# (solver, nfe, eta) costs a dict lookup, not a coefficient solve.

_PLAN_CACHE: dict = {}


def _sde_fingerprint(sde):
    """Hashable identity of an SDE's parameters, or None when the SDE is
    not a plain dataclass (then caching would risk keying on stale state)."""
    if dataclasses.is_dataclass(sde) and not isinstance(sde, type):
        try:
            items = sorted(dataclasses.asdict(sde).items())
        except TypeError:
            return None
        if any(not isinstance(v, (int, float, str, bool, type(None)))
               for _k, v in items):
            return None
        return (type(sde).__name__, tuple(items))
    return None


def cached_make_plan(name: str, sde: SDE, ts, **kw) -> SolverPlan:
    """:func:`make_plan` memoized on ``(family, schedule fingerprint, grid,
    kwargs)``.

    Falls back to an uncached build when the SDE has no stable fingerprint
    (non-dataclass or non-scalar fields). Cached plans are shared objects --
    callers must never mutate them (use ``dataclasses.replace``)."""
    fp = _sde_fingerprint(sde)
    if fp is None:
        return make_plan(name, sde, ts, **kw)
    key = (name.lower(), fp, np.asarray(ts, np.float64).tobytes(),
           tuple(sorted(kw.items())))
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = _PLAN_CACHE[key] = make_plan(name, sde, ts, **kw)
    return plan
