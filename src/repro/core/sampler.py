"""Single pure executor for every :class:`~repro.core.plan.SolverPlan`.

Public API:

  ``sample(plan, eps_fn, x_T, key=None, *, hooks=None)``
      Run the full fixed-step solve (a ``lax.fori_loop`` for ab/rk plans;
      PNDM's warmup is statically unrolled like the original algorithm).
      Returns the final state ``x_0``, or ``(x_0, trajectory)`` when
      ``hooks.record_trajectory`` is set.

  ``step(plan, k, state, eps_fn, *, hooks=None)``
      One solver step as a pure function on an explicit ``SamplerState``.
      This is what serving uses to interleave steps across batches, stream
      per-step progress, and resume mid-solve: ``sample`` is exactly
      ``init_state`` + ``step`` iterated, so splitting a solve across calls
      reproduces the one-shot result (to machine epsilon -- XLA may fuse the
      loop body differently than an eagerly dispatched step). (For ``pndm``
      plans the step index must be a concrete int -- warmup and tail steps
      differ structurally, as in the original algorithm.)

  ``init_state(plan, x_T, key=None)``
      Build the initial ``SamplerState``. Stochastic plans require a PRNG
      key; deterministic plans carry a dummy key untouched.

Everything is a pytree in, pytree out -- ``jax.jit``/``vmap``/``pjit``
compose over ``sample`` and ``step`` with the plan as a traced argument, so
one compiled executor serves every plan with the same :attr:`SolverPlan.signature`.
``Hooks`` are pytree-closed callables (guidance transforms close over arrays;
no Python state), keeping the loop traceable.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

# Module-scope (NOT inside the traced loop body, where a failure would be
# masked until first trace) -- but guarded: only fused plans need Pallas, so
# an environment without it can still import and run every unfused plan.
try:
    from ..kernels.ops import deis_step as _fused_deis_step
except ImportError as _e:  # pragma: no cover - depends on jax build
    _fused_deis_step = None
    _FUSED_IMPORT_ERROR = _e

from .plan import SolverPlan

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]


class SamplerState(NamedTuple):
    """Explicit solver state: everything needed to resume a solve mid-way."""
    x: Array      # current iterate
    hist: Array   # (R, *x.shape) eps history, newest first (R may be 0)
    key: Array    # PRNG key (consumed only by stochastic plans)
    k: Array      # int32 step counter (informational; `step` takes k explicitly)


@dataclasses.dataclass(frozen=True)
class Hooks:
    """Pytree-closed per-step extension points.

    eps_transform: ``(x, t, eps) -> eps`` applied to every network output
        (guidance, thresholding). Must be traceable; closures over arrays ok.
    record_trajectory: when True, ``sample`` also returns the (n_steps, ...)
        stack of post-step iterates.
    """
    eps_transform: Optional[Callable[[Array, Array, Array], Array]] = None
    record_trajectory: bool = False


_DEFAULT_HOOKS = Hooks()


def init_state(plan: SolverPlan, x_T: Array, key: Optional[Array] = None) -> SamplerState:
    if plan.stochastic and key is None:
        raise ValueError(f"stochastic plan (method={plan.method!r}) requires a PRNG key")
    if key is None:
        key = jax.random.PRNGKey(0)
    hist = jnp.zeros((plan.history_len,) + x_T.shape, x_T.dtype)
    return SamplerState(x=x_T, hist=hist, key=key, k=jnp.int32(0))


# ------------------------------------------------------------------ steps
def _apply_eps(hooks: Hooks, x, t, eps):
    return eps if hooks.eps_transform is None else hooks.eps_transform(x, t, eps)


def _step_ab(plan: SolverPlan, k, state: SamplerState, eps_fn: EpsFn,
             hooks: Hooks) -> SamplerState:
    c = plan.coeffs
    x, key = state.x, state.key
    if plan.stochastic:
        key, sub = jax.random.split(key)
    eps = _apply_eps(hooks, x, plan.ts[k], eps_fn(x, plan.ts[k]))
    hist = jnp.concatenate([eps[None], state.hist[:-1]], axis=0)
    if plan.fused:
        if _fused_deis_step is None:
            raise ImportError("plan.fused=True requires the Pallas deis_step "
                              "kernel, which failed to import"
                              ) from _FUSED_IMPORT_ERROR
        flat = x.reshape(-1, x.shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
        hflat = hist.reshape(hist.shape[0], *flat.shape)
        out = _fused_deis_step(flat, hflat, c["psi"][k].astype(jnp.float32),
                               c["C"][k].astype(jnp.float32))
        x_new = out.reshape(x.shape)
    else:
        x_new = c["psi"][k] * x + jnp.tensordot(c["C"][k], hist, axes=1)
    if plan.stochastic:
        xi = jax.random.normal(sub, x.shape, x.dtype)
        x_new = x_new + c["s"][k] * xi
    return SamplerState(x=x_new, hist=hist, key=key, k=state.k + 1)


def _step_rk(plan: SolverPlan, k, state: SamplerState, eps_fn: EpsFn,
             hooks: Hooks) -> SamplerState:
    c = plan.coeffs
    x = state.x
    n_stages = c["b"].shape[0]
    h = c["h"][k]
    y = x / c["mu"][k]
    ks = jnp.zeros((n_stages,) + x.shape, x.dtype)
    for i in range(n_stages):  # static unroll over stages
        y_i = y + h * jnp.tensordot(c["A"][k, i], ks, axes=1)
        x_i = c["stage_mu"][k, i] * y_i
        k_i = _apply_eps(hooks, x_i, c["stage_t"][k, i],
                         eps_fn(x_i, c["stage_t"][k, i]))
        ks = ks.at[i].set(k_i)
    y = y + h * jnp.tensordot(c["b"], ks, axes=1)
    return SamplerState(x=c["mu"][k + 1] * y, hist=state.hist, key=state.key,
                        k=state.k + 1)


_N_WARMUP = 3  # PNDM pseudo-RK4 warmup steps


def _step_pndm(plan: SolverPlan, k: int, state: SamplerState, eps_fn: EpsFn,
               hooks: Hooks) -> SamplerState:
    if isinstance(k, jax.core.Tracer):
        raise TypeError("pndm steps differ structurally between warmup and "
                        "tail; `k` must be a concrete int (python loop)")
    k = int(k)
    c = plan.coeffs
    x = state.x
    if k < _N_WARMUP:
        t_c, t_m, t_n = plan.ts[k], c["warm_t_mid"][k], plan.ts[k + 1]
        rm, cm = c["warm_ratio_m"][k], c["warm_coef_m"][k]
        rn, cn = c["warm_ratio_n"][k], c["warm_coef_n"][k]
        e1 = _apply_eps(hooks, x, t_c, eps_fn(x, t_c))
        x1 = rm * x + cm * e1
        e2 = _apply_eps(hooks, x1, t_m, eps_fn(x1, t_m))
        x2 = rm * x + cm * e2
        e3 = _apply_eps(hooks, x2, t_m, eps_fn(x2, t_m))
        x3 = rn * x + cn * e3
        e4 = _apply_eps(hooks, x3, t_n, eps_fn(x3, t_n))
        e_prime = (e1 + 2 * e2 + 2 * e3 + e4) / 6.0
        x_new = rn * x + cn * e_prime
        hist = jnp.concatenate([e1[None], state.hist[:-1]], axis=0)
    else:
        e = _apply_eps(hooks, x, plan.ts[k], eps_fn(x, plan.ts[k]))
        hist = jnp.concatenate([e[None], state.hist[:-1]], axis=0)
        x_new = c["psi"][k] * x + jnp.tensordot(c["C"][k], hist, axes=1)
    return SamplerState(x=x_new, hist=hist, key=state.key, k=state.k + 1)


_STEPPERS = {"ab": _step_ab, "rk": _step_rk, "pndm": _step_pndm}


def step(plan: SolverPlan, k, state: SamplerState, eps_fn: EpsFn, *,
         hooks: Optional[Hooks] = None) -> SamplerState:
    """Advance one solver step: ``state`` at time ``ts[k]`` -> ``ts[k+1]``."""
    plan = plan.astype(state.x.dtype)
    return _STEPPERS[plan.method](plan, k, state, eps_fn, hooks or _DEFAULT_HOOKS)


def sample(plan: SolverPlan, eps_fn: EpsFn, x_T: Array,
           key: Optional[Array] = None, *, hooks: Optional[Hooks] = None):
    """Run the full solve from ``x_T`` at ``ts[0]`` down to ``ts[-1]``.

    Returns ``x_0``, or ``(x_0, trajectory)`` if ``hooks.record_trajectory``.
    """
    hooks = hooks or _DEFAULT_HOOKS
    state = init_state(plan, x_T, key)
    plan = plan.astype(x_T.dtype)
    n = plan.n_steps
    stepper = _STEPPERS[plan.method]

    if plan.method == "pndm":  # warmup/tail differ structurally: unroll
        traj = []
        for k in range(n):
            state = stepper(plan, k, state, eps_fn, hooks)
            if hooks.record_trajectory:
                traj.append(state.x)
        return (state.x, jnp.stack(traj)) if hooks.record_trajectory else state.x

    if hooks.record_trajectory:
        traj0 = jnp.zeros((n,) + x_T.shape, x_T.dtype)

        def body_t(k, carry):
            st, traj = carry
            st = stepper(plan, k, st, eps_fn, hooks)
            return st, traj.at[k].set(st.x)

        state, traj = jax.lax.fori_loop(0, n, body_t, (state, traj0))
        return state.x, traj

    state = jax.lax.fori_loop(
        0, n, lambda k, st: stepper(plan, k, st, eps_fn, hooks), state)
    return state.x
