"""Single pure executor for every :class:`~repro.core.plan.SolverPlan`.

Public API:

  ``sample(plan, eps_fn, x_T, key=None, *, hooks=None)``
      Run the full fixed-step solve (a ``lax.fori_loop`` for ab/rk plans;
      PNDM's warmup is statically unrolled like the original algorithm).
      Returns the final state ``x_0``, or ``(x_0, trajectory)`` when
      ``hooks.record_trajectory`` is set.

  ``step(plan, k, state, eps_fn, *, hooks=None)``
      One solver step as a pure function on an explicit ``SamplerState``.
      This is what serving uses to interleave steps across batches, stream
      per-step progress, and resume mid-solve: ``sample`` is exactly
      ``init_state`` + ``step`` iterated, so splitting a solve across calls
      reproduces the one-shot result (to machine epsilon -- XLA may fuse the
      loop body differently than an eagerly dispatched step). ``k`` may be a
      tracer for every method (pndm's structural warmup/tail split is a
      ``lax.cond`` under a traced ``k``), so one jitted ``step`` serves all
      step indices of a plan. For a *stacked* plan ``k`` may also be a
      per-row ``(R,)`` int vector: row ``i`` advances from its OWN step
      ``k[i]``, which is what lets serving join a fresh request (at its
      k=0) into a group whose veteran rows are mid-solve. A per-row ``k``
      is clamped to the plan's grid, so retired rows riding a group past
      their own horizon index only inert padded steps.

  ``init_state(plan, x_T, key=None)``
      Build the initial ``SamplerState``. Stochastic plans require a PRNG
      key; deterministic plans carry a dummy key untouched.

Stacked plans (:func:`repro.core.plan.stack_plans`) batch *heterogeneous*
requests: coefficient leaves carry a leading request axis ``R``, ``x`` is
``(R, *inner)`` and ``state.key`` is a ``(R, 2)`` stack of per-request PRNG
keys. Row ``i`` of a stacked solve draws exactly the noise a single-request
solve under ``keys[i]`` would draw (vmapped key splits + per-row draws), which
is what makes streamed serving per-request reproducible.

Everything is a pytree in, pytree out -- ``jax.jit``/``vmap``/``pjit``
compose over ``sample`` and ``step`` with the plan as a traced argument, so
one compiled executor serves every plan with the same :attr:`SolverPlan.signature`.
``Hooks`` are pytree-closed callables (guidance transforms close over arrays;
no Python state), keeping the loop traceable.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

# Module-scope (NOT inside the traced loop body, where a failure would be
# masked until first trace) -- but guarded: only fused plans need Pallas, so
# an environment without it can still import and run every unfused plan.
try:
    from ..kernels.ops import fused_ab_step as _fused_ab_step
except ImportError as _e:  # pragma: no cover - depends on jax build
    _fused_ab_step = None
    _FUSED_IMPORT_ERROR = _e

from .plan import SolverPlan

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]


class SamplerState(NamedTuple):
    """Explicit solver state: everything needed to resume a solve mid-way."""
    x: Array      # current iterate
    hist: Array   # (R, *x.shape) eps history, newest first (R may be 0)
    key: Array    # PRNG key (consumed only by stochastic plans)
    k: Array      # int32 step counter (informational; `step` takes k explicitly)
    err: Array    # running local-error estimate: max-abs (Linf) of the last
    #               step's embedded lower-order difference; (R,) stacked,
    #               scalar unstacked. +inf until the plan produces a first
    #               estimate (plans without `error_estimate`, warmup steps);
    #               steps with zeroed companion weights (inert/padded rows)
    #               leave it unchanged. Linf deliberately: max-reductions are
    #               reduction-order independent, so err is bitwise identical
    #               across batch compositions -- the serving early-exit
    #               invariant (retire at the same k as a solo solve) rests
    #               on this.


@dataclasses.dataclass(frozen=True)
class Hooks:
    """Pytree-closed per-step extension points.

    eps_transform: ``(x, t, eps) -> eps`` applied to every network output
        (guidance, thresholding). Must be traceable; closures over arrays ok.
    record_trajectory: when True, ``sample`` also returns the (n_steps, ...)
        stack of post-step iterates.
    """
    eps_transform: Optional[Callable[[Array, Array, Array], Array]] = None
    record_trajectory: bool = False


_DEFAULT_HOOKS = Hooks()


def init_state(plan: SolverPlan, x_T: Array, key: Optional[Array] = None) -> SamplerState:
    """Build the initial :class:`SamplerState` for ``plan`` at ``x_T``.

    Shape contract: unstacked plans take ``x_T`` of any shape and an optional
    single PRNG key; a stacked plan of ``R`` requests takes ``x_T`` of shape
    ``(R, *inner)`` and per-request keys of shape ``(R, 2)``. ``hist`` is
    allocated as ``(plan.history_len, *x_T.shape)`` zeros. Stochastic plans
    REQUIRE a key (deterministic plans carry a dummy key untouched), which is
    the root of the reproducibility guarantee: every later draw is a pure
    function of this initial key (chain)."""
    if plan.stochastic and key is None:
        raise ValueError(f"stochastic plan (method={plan.method!r}) requires a PRNG key")
    if plan.stacked:
        if x_T.ndim < 1 or x_T.shape[0] != plan.batch:
            raise ValueError(f"stacked plan of {plan.batch} requests needs "
                             f"x_T with leading axis {plan.batch}, got "
                             f"{x_T.shape}")
        if key is None:
            key = jnp.zeros((plan.batch, 2), jnp.uint32)
        if key.ndim != 2 or key.shape[0] != plan.batch:
            raise ValueError(f"stacked plan of {plan.batch} requests needs "
                             f"per-request keys of shape ({plan.batch}, 2), "
                             f"got {key.shape}")
    elif key is None:
        key = jax.random.PRNGKey(0)
    hist = jnp.zeros((plan.history_len,) + x_T.shape, x_T.dtype)
    err = jnp.full(x_T.shape[:1] if plan.stacked else (), jnp.inf, x_T.dtype)
    return SamplerState(x=x_T, hist=hist, key=key, k=jnp.int32(0), err=err)


def take_state_rows(state: SamplerState, rows, shardings=None) -> SamplerState:
    """Row-gather a stacked solve's state: keep requests ``rows``, in order.

    Gathers ``x`` on axis 0, ``hist`` on axis 1 (its layout is
    ``(history_len, R, *inner)``) and the per-request key stack on axis 0;
    the step counter ``k`` is untouched. Because every per-request quantity
    -- including each row's PRNG key chain -- is carried whole, continuing a
    compacted solve is *bit-exact*: surviving row ``i`` takes exactly the
    remaining steps and noise draws it would have taken in the larger stack
    (or solo). This is the state half of mid-flight group compaction; the
    plan half is :func:`repro.core.plan.take_rows`.

    ``shardings`` (a :class:`SamplerState` of ``jax.sharding.Sharding``, e.g.
    built for the NEW batch size via :func:`repro.sharding.rules.state_specs`)
    commits the gathered leaves to those placements, so a compacted state can
    be fed straight to an AOT-compiled sharded executor without a resharding
    recompile -- the sharded half of mid-flight compaction.
    """
    idx = jnp.asarray(rows, dtype=jnp.int32)
    if idx.ndim != 1 or idx.shape[0] == 0:
        raise ValueError(f"rows must be a non-empty 1-D index sequence, got "
                         f"shape {idx.shape}")
    out = SamplerState(x=state.x[idx], hist=state.hist[:, idx],
                       key=state.key[idx], k=state.k, err=state.err[idx])
    if shardings is not None:
        out = jax.device_put(out, shardings)
    return out


def join_state_rows(state: SamplerState, new: SamplerState,
                    shardings=None) -> SamplerState:
    """Splice a fresh stacked state onto an in-flight stacked solve's rows.

    ``new`` is the joiners' own freshly-initialised stacked state (from
    :func:`init_state` at their per-request keys). ``x`` and the key stack
    concatenate on axis 0, ``hist`` on axis 1 (layout ``(history_len, R,
    *inner)``), so the veteran rows' leaves occupy the SAME leading slots
    bit-for-bit -- joining never moves an in-flight request. The joiners
    carry zero eps history and their untouched key chains, exactly what a
    solo solve starts from; stepped with a per-row ``k`` vector (their rows
    at 0, veterans at their own counts) each joiner reproduces its solo
    solve bitwise. ``k`` keeps the veteran state's counter (informational;
    serving tracks per-row counts host-side). This is the state half of
    join-at-compaction; the plan half is :func:`repro.core.plan.join_rows`.

    ``shardings`` (a :class:`SamplerState` of shardings at the NEW batch)
    commits the spliced leaves, mirroring :func:`take_state_rows`.
    """
    if state.key.ndim != 2 or new.key.ndim != 2:
        raise ValueError("join_state_rows splices stacked states (per-request "
                         "(R, 2) key stacks on both sides)")
    if state.hist.shape[0] != new.hist.shape[0]:
        raise ValueError(f"history length mismatch: {state.hist.shape[0]} vs "
                         f"{new.hist.shape[0]} (joiners must share the "
                         "group's plan family)")
    out = SamplerState(x=jnp.concatenate([state.x, new.x], axis=0),
                       hist=jnp.concatenate([state.hist, new.hist], axis=1),
                       key=jnp.concatenate([state.key, new.key], axis=0),
                       k=state.k,
                       err=jnp.concatenate([state.err, new.err], axis=0))
    if shardings is not None:
        out = jax.device_put(out, shardings)
    return out


# ----------------------------------------------------- request-axis sharding
def _request_shardings(plan: SolverPlan, state: SamplerState, mesh):
    """(plan, state) NamedSharding trees for data-parallel stacked execution."""
    from ..sharding.rules import plan_specs, state_specs, to_shardings
    return (to_shardings(plan_specs(plan, mesh), mesh),
            to_shardings(state_specs(state, mesh), mesh))


def shard_state(plan: SolverPlan, state: SamplerState, mesh):
    """Place a stacked (plan, state) pair over ``mesh``'s data axis.

    Every request-axis leaf (x, eps history, the per-request key chains, and
    the plan's per-row coefficient stacks) is committed to a
    ``NamedSharding`` over the data-like axes; scalars replicate. Under a
    trace the placement becomes a sharding constraint instead of a transfer,
    so the same helper serves eager callers and jitted executors.
    """
    plan_sh, state_sh = _request_shardings(plan, state, mesh)
    leaves = jax.tree_util.tree_leaves((plan, state))
    if any(isinstance(l, jax.core.Tracer) for l in leaves):
        place = jax.lax.with_sharding_constraint
    else:
        place = jax.device_put
    return place(plan, plan_sh), place(state, state_sh)


# ------------------------------------------------------------------ steps
def _apply_eps(hooks: Hooks, x, t, eps):
    return eps if hooks.eps_transform is None else hooks.eps_transform(x, t, eps)


def _at_step(v, k, stacked: bool):
    """Per-step (or per-knot) leaf at step index ``k``.

    ``v[k]`` unstacked; ``v[:, k]`` stacked under a group-uniform scalar
    ``k``; ``v[arange(R), k]`` stacked under a per-row ``(R,)`` vector --
    the post-join case where each row runs at its own step count. The
    vector gather picks exactly the same elements a scalar index would when
    all entries agree, so uniform groups stay bitwise identical across the
    two forms."""
    if not stacked:
        return v[k]
    if jnp.ndim(k) == 0:
        return v[:, k]
    return v[jnp.arange(v.shape[0]), k]


def bcast(v, x):
    """Broadcast a per-request coefficient vector (R,) against x (R, *inner).
    No-op on scalars (unstacked plans). This is the stacked-plan broadcasting
    contract; eps oracles that support per-request time vectors (e.g.
    :class:`repro.diffusion.analytic.GaussianData`) share it."""
    return v.reshape(v.shape + (1,) * (x.ndim - v.ndim)) if jnp.ndim(v) else v


def _comb(w, hist, stacked: bool):
    """History combination: sum_j w[j] hist[j] (unstacked, w: (H,)) or
    per-request sum_j w[r, j] hist[j, r] (stacked, w: (R, H))."""
    if stacked:
        return jnp.einsum("rh,hr...->r...", w, hist)
    return jnp.tensordot(w, hist, axes=1)


def _update_err(loc, live, prev, stacked: bool):
    """Fold one step's embedded-pair difference ``loc`` into the running
    per-row estimate: Linf (max-abs over inner dims) where the companion
    weights were live, previous value elsewhere (warmup rows, inert/padded
    steps -- their zeroed weights would read as spurious convergence)."""
    axes = tuple(range(1, loc.ndim)) if stacked else None
    raw = jnp.max(jnp.abs(loc), axis=axes)
    return jnp.where(live, raw, prev)


def _split_keys(key, stacked: bool):
    """split() that treats a (R, 2) leaf as R independent per-request keys."""
    if stacked:
        ks = jax.vmap(jax.random.split)(key)   # (R, 2, 2)
        return ks[:, 0], ks[:, 1]
    return jax.random.split(key)


def _noise_like(sub, x, stacked: bool):
    """Per-request draws match what a single-request solve under keys[r]
    would draw: normal(keys[r], inner_shape) row by row."""
    if stacked:
        return jax.vmap(
            lambda kk: jax.random.normal(kk, x.shape[1:], x.dtype))(sub)
    return jax.random.normal(sub, x.shape, x.dtype)


def _step_ab(plan: SolverPlan, k, state: SamplerState, eps_fn: EpsFn,
             hooks: Hooks) -> SamplerState:
    c, stk = plan.coeffs, plan.stacked
    x, key = state.x, state.key
    if plan.stochastic:
        key, sub = _split_keys(key, stk)
    t_k = _at_step(plan.ts, k, stk)
    psi = _at_step(c["psi"], k, stk)
    Cw = _at_step(c["C"], k, stk)
    if "nu" in c:
        # score-normalized families (sndeis): the polynomial was fitted to
        # eps/ell, so history entry j is weighted by C[k, j] * nu[k, j]
        nu = _at_step(c["nu"], k, stk)
        Cw = Cw * nu
    eps = _apply_eps(hooks, x, t_k, eps_fn(x, t_k))
    hist = jnp.concatenate([eps[None], state.hist[:-1]], axis=0)
    s_coef = noise = None
    if plan.stochastic:
        s_coef = _at_step(c["s"], k, stk)
        noise = _noise_like(sub, x, stk)
    Ew = live = None
    if "E" in c:
        Ew = _at_step(c["E"], k, stk)
        live = jnp.any(Ew != 0, axis=-1)
        if "nu" in c:
            Ew = Ew * nu          # the pair difference is normalized too
    if plan.fused:
        if _fused_ab_step is None:
            raise ImportError("plan.fused=True requires the Pallas deis_step "
                              "kernel, which failed to import"
                              ) from _FUSED_IMPORT_ERROR
        # Flatten to the kernel's (R, M, D) layout. Unstacked solves run as a
        # one-row stack, so solo and stacked groups share the same per-block
        # arithmetic (the serving bitwise-vs-solo invariant). Noise draw and
        # error-pair combination ride in the same kernel call: one HBM round
        # trip instead of r+3.
        n_rows = x.shape[0] if stk else 1
        inner = x.shape[1:] if stk else x.shape
        m = 1
        for dim in inner[:-1]:
            m *= dim
        d = inner[-1] if inner else 1
        xf = x.reshape(n_rows, m, d)
        hf = hist.reshape(hist.shape[0], n_rows, m, d)
        if stk:
            psi_r, C_r, s_r, E_r = psi, Cw, s_coef, Ew
        else:
            psi_r = jnp.reshape(psi, (1,))
            C_r = Cw[None]
            s_r = jnp.reshape(s_coef, (1,)) if s_coef is not None else None
            E_r = Ew[None] if Ew is not None else None
        n_r = noise.reshape(xf.shape) if noise is not None else None
        out, err_raw = _fused_ab_step(xf, hf, psi_r, C_r, s=s_r, noise=n_r,
                                      err_coeffs=E_r)
        x_new = out.reshape(x.shape)
        if Ew is not None:
            raw = err_raw if stk else err_raw[0]
            err = jnp.where(live, raw.astype(state.err.dtype), state.err)
        else:
            err = state.err
    else:
        x_new = bcast(psi, x) * x + _comb(Cw, hist, stk)
        if plan.stochastic:
            x_new = x_new + bcast(s_coef, x) * noise
        if Ew is not None:
            err = _update_err(_comb(Ew, hist, stk), live, state.err, stk)
        else:
            err = state.err
    return SamplerState(x=x_new, hist=hist, key=key, k=state.k + 1, err=err)


def _step_rk(plan: SolverPlan, k, state: SamplerState, eps_fn: EpsFn,
             hooks: Hooks) -> SamplerState:
    c, stk = plan.coeffs, plan.stacked
    x = state.x
    n_stages = c["b"].shape[-1]
    h = _at_step(c["h"], k, stk)
    A_k = _at_step(c["A"], k, stk)                   # (R, S, S) / (S, S)
    stage_mu = _at_step(c["stage_mu"], k, stk)       # (R, S) / (S,)
    stage_t = _at_step(c["stage_t"], k, stk)
    y = x / bcast(_at_step(c["mu"], k, stk), x)
    ks = jnp.zeros((n_stages,) + x.shape, x.dtype)
    for i in range(n_stages):  # static unroll over stages
        y_i = y + bcast(h, x) * _comb(A_k[..., i, :], ks, stk)
        x_i = bcast(stage_mu[..., i], x) * y_i
        st_t = stage_t[..., i]
        k_i = _apply_eps(hooks, x_i, st_t, eps_fn(x_i, st_t))
        ks = ks.at[i].set(k_i)
    y = y + bcast(h, x) * _comb(c["b"], ks, stk)
    mu_next = _at_step(c["mu"], k + 1, stk)
    if "b_err" in c:
        # embedded pair difference, mapped to x-space through the same
        # mu-weighting the iterate gets
        loc = bcast(mu_next, x) * (bcast(h, x) * _comb(c["b_err"], ks, stk))
        err = _update_err(loc, h != 0, state.err, stk)
    else:
        err = state.err
    return SamplerState(x=bcast(mu_next, x) * y,
                        hist=state.hist, key=state.key, k=state.k + 1,
                        err=err)


_N_WARMUP = 3  # PNDM pseudo-RK4 warmup steps


def _pndm_warmup(plan: SolverPlan, k, state: SamplerState, eps_fn: EpsFn,
                 hooks: Hooks) -> SamplerState:
    """Pseudo-RK4 warmup step (4 NFE). ``k`` may be traced; warm-coefficient
    indices are clamped so the trace stays valid for any k (the tail branch
    of the traced `lax.cond` never executes this at k >= _N_WARMUP, and the
    per-row mixed path masks warm rows explicitly)."""
    c, stk = plan.coeffs, plan.stacked
    x = state.x
    if isinstance(k, jax.core.Tracer) or jnp.ndim(k):
        kw = jnp.minimum(k, _N_WARMUP - 1)
    else:
        kw = k
    t_c, t_m, t_n = (_at_step(plan.ts, k, stk), _at_step(c["warm_t_mid"], kw, stk),
                     _at_step(plan.ts, k + 1, stk))
    rm, cm = _at_step(c["warm_ratio_m"], kw, stk), _at_step(c["warm_coef_m"], kw, stk)
    rn, cn = _at_step(c["warm_ratio_n"], kw, stk), _at_step(c["warm_coef_n"], kw, stk)
    rm, cm = bcast(rm, x), bcast(cm, x)
    rn, cn = bcast(rn, x), bcast(cn, x)
    e1 = _apply_eps(hooks, x, t_c, eps_fn(x, t_c))
    x1 = rm * x + cm * e1
    e2 = _apply_eps(hooks, x1, t_m, eps_fn(x1, t_m))
    x2 = rm * x + cm * e2
    e3 = _apply_eps(hooks, x2, t_m, eps_fn(x2, t_m))
    x3 = rn * x + cn * e3
    e4 = _apply_eps(hooks, x3, t_n, eps_fn(x3, t_n))
    e_prime = (e1 + 2 * e2 + 2 * e3 + e4) / 6.0
    x_new = rn * x + cn * e_prime
    hist = jnp.concatenate([e1[None], state.hist[:-1]], axis=0)
    # warmup has no embedded pair: err passes through (stays +inf pre-tail)
    return SamplerState(x=x_new, hist=hist, key=state.key, k=state.k + 1,
                        err=state.err)


def _pndm_tail(plan: SolverPlan, k, state: SamplerState, eps_fn: EpsFn,
               hooks: Hooks) -> SamplerState:
    c, stk = plan.coeffs, plan.stacked
    x = state.x
    t_k = _at_step(plan.ts, k, stk)
    psi = _at_step(c["psi"], k, stk)
    Cw = _at_step(c["C"], k, stk)
    e = _apply_eps(hooks, x, t_k, eps_fn(x, t_k))
    hist = jnp.concatenate([e[None], state.hist[:-1]], axis=0)
    x_new = bcast(psi, x) * x + _comb(Cw, hist, stk)
    if "E" in c:
        Ew = _at_step(c["E"], k, stk)
        err = _update_err(_comb(Ew, hist, stk), jnp.any(Ew != 0, axis=-1),
                          state.err, stk)
    else:
        err = state.err
    return SamplerState(x=x_new, hist=hist, key=state.key, k=state.k + 1,
                        err=err)


def _pndm_rowwise(plan: SolverPlan, k, state: SamplerState, eps_fn: EpsFn,
                  hooks: Hooks) -> SamplerState:
    """Per-row ``k`` vector: rows of a post-join group may sit on either
    side of pndm's structural warmup/tail split. All-warmup and all-tail
    groups stage exactly one branch via nested ``lax.cond``; a genuinely
    mixed group computes both branches (5 net evals that step) and selects
    rows -- joins across the warmup boundary are correct, just not free."""
    warm = lambda st: _pndm_warmup(plan, k, st, eps_fn, hooks)
    tail = lambda st: _pndm_tail(plan, k, st, eps_fn, hooks)

    def mixed(st):
        w, t = warm(st), tail(st)
        m = bcast(k < _N_WARMUP, st.x)               # (R, 1, ...)
        return SamplerState(x=jnp.where(m, w.x, t.x),
                            hist=jnp.where(m[None], w.hist, t.hist),
                            key=st.key, k=st.k + 1,
                            err=jnp.where(k < _N_WARMUP, w.err, t.err))

    return jax.lax.cond(
        jnp.all(k < _N_WARMUP), warm,
        lambda st: jax.lax.cond(jnp.any(k < _N_WARMUP), mixed, tail, st),
        state)


def _step_pndm(plan: SolverPlan, k, state: SamplerState, eps_fn: EpsFn,
               hooks: Hooks) -> SamplerState:
    if jnp.ndim(k):
        return _pndm_rowwise(plan, k, state, eps_fn, hooks)
    if isinstance(k, jax.core.Tracer):
        # warmup and tail differ structurally (4 vs 1 net evals); under a
        # traced k both are staged and `lax.cond` executes only the taken
        # branch -- this is what lets serving jit ONE step for all k.
        return jax.lax.cond(
            k < _N_WARMUP,
            lambda st: _pndm_warmup(plan, k, st, eps_fn, hooks),
            lambda st: _pndm_tail(plan, k, st, eps_fn, hooks),
            state)
    k = int(k)  # repro: allow[RL001] eager path: traced k returned via lax.cond above
    if k < _N_WARMUP:
        return _pndm_warmup(plan, k, state, eps_fn, hooks)
    return _pndm_tail(plan, k, state, eps_fn, hooks)


_STEPPERS = {"ab": _step_ab, "rk": _step_rk, "pndm": _step_pndm}


def step(plan: SolverPlan, k, state: SamplerState, eps_fn: EpsFn, *,
         hooks: Optional[Hooks] = None, mesh=None) -> SamplerState:
    """Advance one solver step: ``state`` at time ``ts[k]`` -> ``ts[k+1]``.

    For a stacked plan ``k`` may be a per-row ``(R,)`` int vector: row ``i``
    steps from ITS index ``k[i]`` (a serving group whose rows were admitted
    at different ticks). Entries are clamped to the plan's grid, so a row
    riding past its own horizon indexes only inert padded coefficients.

    ``mesh`` (a ``jax.sharding.Mesh`` with a data-like axis) places the
    stacked request axis of every state/plan leaf with a ``NamedSharding``
    before stepping -- data-parallel execution over requests. Sharding never
    changes WHAT is computed (row ``i`` is row ``i``'s solo solve, bitwise);
    serving's AOT executors instead jit with explicit in/out shardings and
    pass no mesh here.
    """
    plan = plan.astype(state.x.dtype)
    if jnp.ndim(k):
        if not plan.stacked:
            raise ValueError("a per-row k vector requires a stacked plan")
        k = jnp.minimum(jnp.asarray(k, jnp.int32), plan.n_steps - 1)
    if mesh is not None:
        plan, state = shard_state(plan, state, mesh)
    return _STEPPERS[plan.method](plan, k, state, eps_fn, hooks or _DEFAULT_HOOKS)


def sample(plan: SolverPlan, eps_fn: EpsFn, x_T: Array,
           key: Optional[Array] = None, *, hooks: Optional[Hooks] = None,
           mesh=None, tracer=None):
    """Run the full solve from ``x_T`` at ``ts[0]`` down to ``ts[-1]``.

    Returns ``x_0``, or ``(x_0, trajectory)`` if ``hooks.record_trajectory``.

    ``tracer`` (a :class:`repro.obs.trace.Tracer`) opts into step-level
    timing OFF the jitted path: the ab/rk loop runs as eagerly dispatched
    steps instead of ``lax.fori_loop``, each wrapped in a ``sample.step``
    span. Spans time host-side dispatch only and never force a device sync
    (no ``block_until_ready`` anywhere); to attribute device time, construct
    the tracer with ``annotate=True`` under a ``jax.profiler`` trace. Eager
    stepping matches the fori_loop result to machine epsilon (same caveat as
    ``sample`` vs an eagerly dispatched ``step`` loop above). Leave ``None``
    on the hot path -- the traced loop stays byte-identical to before.

    ``mesh`` shards a *stacked* solve's request axis over the mesh's
    data-like axes before the loop; sharding propagates through the loop
    body, so every step runs data-parallel over requests. Rows never mix:
    in float32 (the serving dtype) results are bitwise identical to the
    single-device solve; under float64 the SPMD-partitioned loop body may
    fuse differently and differ by 1 ulp (the same caveat as ``sample`` vs
    an eagerly dispatched ``step`` loop). Serving's per-step AOT executors
    are bitwise on both paths.
    """
    hooks = hooks or _DEFAULT_HOOKS
    state = init_state(plan, x_T, key)
    plan = plan.astype(x_T.dtype)
    if mesh is not None:
        plan, state = shard_state(plan, state, mesh)
    n = plan.n_steps
    stepper = _STEPPERS[plan.method]

    # pndm's warmup/tail differ structurally, so it always unrolls; a tracer
    # forces the same eager loop for ab/rk so each step gets its own span.
    if plan.method == "pndm" or tracer is not None:
        span = (tracer.span if tracer is not None
                else lambda _name: contextlib.nullcontext())
        traj = []
        for k in range(n):
            with span("sample.step"):
                state = stepper(plan, k, state, eps_fn, hooks)
            if hooks.record_trajectory:
                traj.append(state.x)
        return (state.x, jnp.stack(traj)) if hooks.record_trajectory else state.x

    if hooks.record_trajectory:
        traj0 = jnp.zeros((n,) + x_T.shape, x_T.dtype)

        def body_t(k, carry):
            st, traj = carry
            st = stepper(plan, k, st, eps_fn, hooks)
            return st, traj.at[k].set(st.x)

        state, traj = jax.lax.fori_loop(0, n, body_t, (state, traj0))
        return state.x, traj

    state = jax.lax.fori_loop(
        0, n, lambda k, st: stepper(plan, k, st, eps_fn, hooks), state)
    return state.x
