"""Timestep schedules (paper Ingredient 4; App. H.3 Eqs. 42-44).

All schedules return a *decreasing* array ``ts`` of length N+1 with
``ts[0] = T`` (= t_N in the paper's indexing) and ``ts[-1] = t0``.
The sampler steps through consecutive pairs (ts[k], ts[k+1]).
"""
from __future__ import annotations

import numpy as np

from .sde import SDE


def uniform_t(sde: SDE, n: int, t0: float | None = None) -> np.ndarray:
    """Uniform step in t (paper's 'linear timesteps')."""
    t0 = sde.t0 if t0 is None else t0
    return np.linspace(sde.T, t0, n + 1)


def power_t(sde: SDE, n: int, t0: float | None = None, kappa: float = 2.0) -> np.ndarray:
    """Power schedule in t (Eq. 42); kappa=2 is the DDIM 'quadratic' schedule."""
    t0 = sde.t0 if t0 is None else t0
    i = np.arange(n + 1)
    return ((n - i) / n * sde.T ** (1.0 / kappa) + i / n * t0 ** (1.0 / kappa)) ** kappa


def power_rho(sde: SDE, n: int, t0: float | None = None, kappa: float = 7.0) -> np.ndarray:
    """Power schedule in rho (Eq. 43); kappa=7 is the EDM/Karras schedule."""
    t0 = sde.t0 if t0 is None else t0
    rho_lo, rho_hi = float(sde.rho(t0)), float(sde.rho(sde.T))
    i = np.arange(n + 1)
    rhos = ((n - i) / n * rho_hi ** (1.0 / kappa) + i / n * rho_lo ** (1.0 / kappa)) ** kappa
    return np.asarray(sde.t_of_rho(rhos), dtype=np.float64)


def log_rho(sde: SDE, n: int, t0: float | None = None) -> np.ndarray:
    """Uniform in log rho (Eq. 44); equivalent to uniform log-SNR (DPM-Solver)."""
    t0 = sde.t0 if t0 is None else t0
    rho_lo, rho_hi = float(sde.rho(t0)), float(sde.rho(sde.T))
    i = np.arange(n + 1)
    rhos = np.exp((n - i) / n * np.log(rho_hi) + i / n * np.log(rho_lo))
    return np.asarray(sde.t_of_rho(rhos), dtype=np.float64)


SCHEDULES = {
    "uniform": uniform_t,
    "quadratic": lambda sde, n, t0=None: power_t(sde, n, t0, kappa=2.0),
    "power_t": power_t,
    "power_rho": power_rho,
    "edm": lambda sde, n, t0=None: power_rho(sde, n, t0, kappa=7.0),
    "log_rho": log_rho,
}


def get_timesteps(sde: SDE, n: int, schedule: str = "quadratic",
                  t0: float | None = None, **kw) -> np.ndarray:
    try:
        fn = SCHEDULES[schedule]
    except KeyError:
        raise ValueError(f"unknown schedule {schedule!r}; have {sorted(SCHEDULES)}")
    ts = fn(sde, n, t0, **kw) if kw else fn(sde, n, t0)
    if not (np.all(np.diff(ts) < 0) and ts[0] > ts[-1]):
        raise AssertionError("timesteps must be strictly decreasing from T to t0")
    return ts
