"""Forward diffusion SDEs (paper Sec. 2, Tab. 1).

All SDEs here are scalar-coefficient linear diffusions

    dx = f(t) x dt + g(t) dw,          x in R^D,

with Gaussian conditionals  p_{0t}(x_t | x_0) = N(mu(t) x_0, sigma(t)^2 I).

Notation maps to the paper as follows (paper uses matrix F_t, G_t; every SDE we
instantiate is isotropic so scalars suffice -- the coefficient engine in
``coeffs.py`` only needs mu/sigma/rho):

    F_t = f(t) I,  G_t = g(t) I,  mu_t = mu(t) I,  Sigma_t = sigma(t)^2 I,
    L_t = sigma(t) I,  Psi(t, s) = mu(t)/mu(s) I,
    rho(t) = sigma(t)/mu(t)                  (the DEIS time rescaling, Prop. 3).

The key identity used throughout (verified in tests against the paper's
closed-form Prop. 2 coefficients):

    (1/2) Psi(t', tau) g(tau)^2 / sigma(tau) dtau = mu(t') drho(tau).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax.numpy as jnp
import numpy as np


class SDE:
    """Scalar-coefficient linear forward SDE."""

    #: sampling integration endpoints (overridable per instance)
    T: float = 1.0
    t0: float = 1e-3

    # ---- primitive schedule ------------------------------------------------
    def mu(self, t):
        """Signal coefficient of p_{0t} (paper's sqrt(alpha_t) for VPSDE)."""
        raise NotImplementedError

    def sigma(self, t):
        """Noise std of p_{0t}."""
        raise NotImplementedError

    # ---- derived quantities ------------------------------------------------
    def f(self, t):
        """Drift coefficient f(t) = d log mu / dt (numeric default)."""
        return _central_diff(lambda u: np.log(self.mu(u)), t)

    def g2(self, t):
        """g(t)^2 = d sigma^2/dt - 2 f sigma^2 (numeric default)."""
        ds2 = _central_diff(lambda u: self.sigma(u) ** 2, t)
        return ds2 - 2.0 * self.f(t) * self.sigma(t) ** 2

    def psi(self, t, s):
        """Transition 'matrix' Psi(t, s) = mu(t)/mu(s)."""
        return self.mu(t) / self.mu(s)

    def rho(self, t):
        """DEIS rescaled time rho(t) = sigma(t)/mu(t) (Prop. 3, up to mu(0)~1)."""
        return self.sigma(t) / self.mu(t)

    def t_of_rho(self, rho):
        """Inverse of rho(t); generic bisection fallback."""
        lo = np.full_like(np.asarray(rho, dtype=np.float64), 0.0)
        hi = np.full_like(lo, self.T)
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            val = self.rho(mid)
            lo = np.where(val < rho, mid, lo)
            hi = np.where(val < rho, hi, mid)
        return 0.5 * (lo + hi)

    # ---- sampling-side helpers ----------------------------------------------
    def prior_std(self):
        """Std of pi(x_T) used to draw x_T (paper: N(0, Sigma_T) or N(0, mu_T^2+sigma_T^2))."""
        return math.sqrt(self.mu(self.T) ** 2 + self.sigma(self.T) ** 2)

    def marginal_sample(self, key, x0, t):
        """Draw x_t ~ p_{0t}(. | x_0). ``t`` scalar."""
        import jax
        eps = jax.random.normal(key, x0.shape, x0.dtype)
        return self.mu(t) * x0 + self.sigma(t) * eps, eps

    def score_from_eps(self, eps, t):
        """score = -L_t^{-T} eps = -eps / sigma(t)."""
        return -eps / self.sigma(t)

    def eps_from_score(self, score, t):
        return -score * self.sigma(t)


def _central_diff(fn: Callable, t, h: float = 1e-5):
    t = np.asarray(t, dtype=np.float64)
    return (fn(t + h) - fn(t - h)) / (2.0 * h)


@dataclasses.dataclass
class VPSDE(SDE):
    """Variance-preserving SDE (Ho et al. 2020; paper Tab. 1).

    log alpha_bar(t) = -0.25 t^2 (beta_max - beta_min) - 0.5 t beta_min
    mu(t) = sqrt(alpha_bar(t)),  sigma(t) = sqrt(1 - alpha_bar(t)).
    """

    beta_min: float = 0.1
    beta_max: float = 20.0
    T: float = 1.0
    t0: float = 1e-3

    def log_alpha_bar(self, t):
        # log alpha_bar(t) = -int_0^t beta = -(0.5 t^2 (bmax-bmin) + t bmin),
        # so that d log alpha_bar/dt = -beta(t), f = -beta/2, g^2 = beta.
        t = _as_np_or_jnp(t)
        return -0.5 * t ** 2 * (self.beta_max - self.beta_min) - t * self.beta_min

    def alpha_bar(self, t):
        mod = jnp if _is_traced(t) else np
        return mod.exp(self.log_alpha_bar(t))

    def beta(self, t):
        return self.beta_min + t * (self.beta_max - self.beta_min)

    def mu(self, t):
        mod = jnp if _is_traced(t) else np
        return mod.exp(0.5 * self.log_alpha_bar(t))

    def sigma(self, t):
        mod = jnp if _is_traced(t) else np
        return mod.sqrt(-mod.expm1(self.log_alpha_bar(t)))

    def f(self, t):
        return -0.5 * self.beta(t)

    def g2(self, t):
        return self.beta(t)

    def t_of_rho(self, rho):
        """Closed form: alpha_bar = 1/(1+rho^2) and solve the quadratic in t."""
        rho = np.asarray(rho, dtype=np.float64)
        c = np.log1p(rho ** 2)  # = -log alpha_bar
        a = 0.5 * (self.beta_max - self.beta_min)
        b = self.beta_min
        return (-b + np.sqrt(b ** 2 + 4.0 * a * c)) / (2.0 * a)

    def prior_std(self):
        return 1.0  # mu_T^2 + sigma_T^2 = 1 exactly for VP


@dataclasses.dataclass
class VESDE(SDE):
    """Variance-exploding SDE (Song et al. 2020b; paper Tab. 1).

    mu(t) = 1,  sigma(t) = sigma_min (sigma_max/sigma_min)^t.
    """

    sigma_min: float = 0.02
    sigma_max: float = 100.0
    T: float = 1.0
    t0: float = 1e-5

    def mu(self, t):
        mod = jnp if _is_traced(t) else np
        return mod.ones_like(mod.asarray(t, dtype=mod.float64 if mod is np else None)) * 1.0

    def sigma(self, t):
        mod = jnp if _is_traced(t) else np
        log_ratio = math.log(self.sigma_max / self.sigma_min)
        return self.sigma_min * mod.exp(mod.asarray(t) * log_ratio)

    def f(self, t):
        return np.zeros_like(np.asarray(t, dtype=np.float64))

    def g2(self, t):
        log_ratio = math.log(self.sigma_max / self.sigma_min)
        return 2.0 * log_ratio * self.sigma(t) ** 2

    def psi(self, t, s):
        return np.ones_like(np.asarray(t, dtype=np.float64) * np.asarray(s, dtype=np.float64))

    def rho(self, t):
        return self.sigma(t)

    def t_of_rho(self, rho):
        rho = np.asarray(rho, dtype=np.float64)
        return np.log(rho / self.sigma_min) / math.log(self.sigma_max / self.sigma_min)

    def prior_std(self):
        return math.sqrt(1.0 + self.sigma(self.T) ** 2)


@dataclasses.dataclass
class SubVPSDE(VPSDE):
    """sub-VP SDE (Song et al. 2020b) -- extra SDE beyond the paper's two, to
    demonstrate the coefficient engine is SDE-generic."""

    def sigma(self, t):
        mod = jnp if _is_traced(t) else np
        return -mod.expm1(self.log_alpha_bar(t))  # 1 - alpha_bar

    def g2(self, t):
        mod = jnp if _is_traced(t) else np
        return self.beta(t) * (-mod.expm1(2.0 * self.log_alpha_bar(t)))

    def t_of_rho(self, rho):
        # rho = (1-ab)/sqrt(ab); solve ab from quadratic ab rho^2 = (1-ab)^2
        rho = np.asarray(rho, dtype=np.float64)
        # (1-ab)^2 - rho^2 ab = 0 -> ab^2 - (2+rho^2) ab + 1 = 0, take root < 1
        ab = 0.5 * ((2.0 + rho ** 2) - np.sqrt((2.0 + rho ** 2) ** 2 - 4.0))
        c = -np.log(ab)
        a = 0.5 * (self.beta_max - self.beta_min)
        b = self.beta_min
        return (-b + np.sqrt(b ** 2 + 4.0 * a * c)) / (2.0 * a)


def _is_traced(t) -> bool:
    return isinstance(t, jnp.ndarray) and not isinstance(t, np.ndarray)


def _as_np_or_jnp(t):
    if _is_traced(t):
        return t
    return np.asarray(t, dtype=np.float64)


def get_sde(name: str, **kw) -> SDE:
    name = name.lower()
    if name in ("vp", "vpsde"):
        return VPSDE(**kw)
    if name in ("ve", "vesde"):
        return VESDE(**kw)
    if name in ("subvp", "subvpsde"):
        return SubVPSDE(**kw)
    raise ValueError(f"unknown SDE {name!r}")
