"""DEIS solver family + baselines (paper Secs. 3-4, App. H.2).

Every solver is built once on the host (float64 numpy coefficient precompute)
and exposes a jit-compatible ``sample(eps_fn, x_T, key=None)`` driving a
``lax.fori_loop``. ``eps_fn(x, t_scalar) -> eps`` is the noise-prediction
network (paper's Ingredient 2 parameterization); closures over parameters are
fine and the loop is shardable under pjit.

Solvers:
  ABSolver        tAB-DEIS / rhoAB-DEIS, r in {0..3}; r=0 == deterministic DDIM
                  (Prop. 2, tested); also 'naive EI' coefficients for Fig. 3.
  RKSolver        rhoRK-DEIS on the transformed ODE dy/drho = eps-hat (Prop. 3):
                  heun (== EDM/Karras, App. B Q4), midpoint (DPM-Solver2
                  analogue, App. B Q5), kutta3, rk4.
  EulerSolver     Euler on the x-space PF-ODE (Song et al. baseline).
  EMSolver        Euler-Maruyama on the lambda-SDE (Eq. 4), lambda=1 default.
  DDIMSolver      stochastic DDIM(eta) for VPSDE (Prop. 4).
  IPNDMSolver     improved PNDM (App. H.2): classical uniform-grid AB weights
                  with lower-order warmup + DDIM transfer.
  PNDMSolver      original PNDM: pseudo-RK4 warmup (4 NFE x 3 steps) + AB4.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import coeffs as C
from .sde import SDE, VPSDE

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]


def _f64(x):
    return np.asarray(x, dtype=np.float64)


@dataclasses.dataclass
class SolverBase:
    name: str
    nfe: int
    sde: SDE
    ts: np.ndarray

    def sample(self, eps_fn: EpsFn, x_T: Array, key: Optional[Array] = None) -> Array:
        raise NotImplementedError


class ABSolver(SolverBase):
    """Exponential-integrator Adams-Bashforth (tAB/rhoAB-DEIS; r=0 is DDIM).

    fused_update=True routes the Eq. 14 multistep combination through the
    Pallas ``deis_step`` kernel (one HBM round-trip instead of r+2 on TPU;
    interpret-mode on CPU -- equivalence-tested in tests/test_kernels.py).
    """

    def __init__(self, sde: SDE, ts, order: int = 0, basis: str = "t",
                 name: str | None = None, naive_ei: bool = False,
                 fused_update: bool = False):
        ts = _f64(ts)
        super().__init__(name or f"{basis}AB{order}", len(ts) - 1, sde, ts)
        self.order = order
        self.fused_update = fused_update
        if naive_ei:
            if order != 0:
                raise ValueError("naive EI is zero-order only")
            psi, Cm = C.naive_ei_coefficients(sde, ts)
        else:
            psi, Cm = C.ab_coefficients(sde, ts, order, basis)
        self.psi, self.C = psi, Cm

    def sample(self, eps_fn, x_T, key=None):
        n, order = len(self.ts) - 1, self.order
        dtype = x_T.dtype
        psi = jnp.asarray(self.psi, dtype)
        Cm = jnp.asarray(self.C, dtype)
        t_arr = jnp.asarray(self.ts, dtype)
        fused = self.fused_update

        def body(k, carry):
            x, hist = carry
            eps = eps_fn(x, t_arr[k])
            hist = jnp.concatenate([eps[None], hist[:-1]], axis=0)
            if fused:
                from ..kernels.ops import deis_step as _fused
                flat = x.reshape(-1, x.shape[-1]) if x.ndim > 1 else x.reshape(1, -1)
                hflat = hist.reshape(hist.shape[0], *flat.shape)
                out = _fused(flat, hflat, psi[k].astype(jnp.float32),
                             Cm[k].astype(jnp.float32))
                x = out.reshape(x.shape)
            else:
                comb = jnp.tensordot(Cm[k], hist, axes=1)
                x = psi[k] * x + comb
            return x, hist

        hist0 = jnp.zeros((order + 1,) + x_T.shape, dtype)
        x, _ = jax.lax.fori_loop(0, n, body, (x_T, hist0))
        return x


_TABLEAUS = {
    "heun": (np.array([0.0, 1.0]),
             [np.array([]), np.array([1.0])],
             np.array([0.5, 0.5])),
    "midpoint": (np.array([0.0, 0.5]),
                 [np.array([]), np.array([0.5])],
                 np.array([0.0, 1.0])),
    "kutta3": (np.array([0.0, 0.5, 1.0]),
               [np.array([]), np.array([0.5]), np.array([-1.0, 2.0])],
               np.array([1.0, 4.0, 1.0]) / 6.0),
    "rk4": (np.array([0.0, 0.5, 0.5, 1.0]),
            [np.array([]), np.array([0.5]), np.array([0.0, 0.5]), np.array([0.0, 0.0, 1.0])],
            np.array([1.0, 2.0, 2.0, 1.0]) / 6.0),
}


class RKSolver(SolverBase):
    """rhoRK-DEIS: classical explicit RK on dy/drho = eps_hat(y, rho) (Eq. 17)."""

    def __init__(self, sde: SDE, ts, method: str = "heun", name: str | None = None):
        ts = _f64(ts)
        c, a, b = _TABLEAUS[method]
        super().__init__(name or f"rho_{method}", (len(ts) - 1) * len(c), sde, ts)
        self.method, self.c, self.a, self.b = method, c, a, b
        rho = _f64(sde.rho(ts))
        self.h = rho[1:] - rho[:-1]  # negative steps
        # stage times/scales, shape (N, S): rho_s = rho_k + c_s * h_k
        stage_rho = rho[:-1, None] + c[None, :] * self.h[:, None]
        stage_rho = np.maximum(stage_rho, float(sde.rho(ts[-1])) * (1 - 1e-12))
        self.stage_t = _f64(sde.t_of_rho(stage_rho))
        self.stage_mu = _f64(sde.mu(self.stage_t))
        self.mu = _f64(sde.mu(ts))

    def sample(self, eps_fn, x_T, key=None):
        n = len(self.ts) - 1
        dtype = x_T.dtype
        s = len(self.c)
        h = jnp.asarray(self.h, dtype)
        st_t = jnp.asarray(self.stage_t, dtype)
        st_mu = jnp.asarray(self.stage_mu, dtype)
        mu = jnp.asarray(self.mu, dtype)
        a_mat = np.zeros((s, s))
        for i, row in enumerate(self.a):
            a_mat[i, : len(row)] = row
        a_mat = jnp.asarray(a_mat, dtype)
        b = jnp.asarray(self.b, dtype)

        def body(k, x):
            y = x / mu[k]
            ks = jnp.zeros((s,) + x.shape, dtype)
            for i in range(s):  # static unroll over stages
                y_i = y + h[k] * jnp.tensordot(a_mat[i], ks, axes=1)
                k_i = eps_fn(st_mu[k, i] * y_i, st_t[k, i])
                ks = ks.at[i].set(k_i)
            y = y + h[k] * jnp.tensordot(b, ks, axes=1)
            return mu[k + 1] * y

        return jax.lax.fori_loop(0, n, body, x_T)


class DPMSolver2(RKSolver):
    """DPM-Solver-2 (Lu et al. 2022; paper App. B Q5, Algo 2): the midpoint
    method in half-log-SNR lambda = -log rho. Identical to rhoRK-midpoint
    except the stage sits at the GEOMETRIC mean of (rho_k, rho_{k+1}) instead
    of the arithmetic mean -- implemented here to reproduce the paper's
    Table 3 comparison."""

    def __init__(self, sde: SDE, ts, name: str = "dpm2"):
        super().__init__(sde, ts, method="midpoint", name=name)
        ts = self.ts
        rho = _f64(sde.rho(ts))
        lam = -np.log(rho)
        stage_lam = np.stack([lam[:-1],
                              0.5 * (lam[:-1] + lam[1:])], axis=1)
        stage_rho = np.exp(-stage_lam)
        self.stage_t = _f64(sde.t_of_rho(stage_rho))
        self.stage_mu = _f64(sde.mu(self.stage_t))
        # midpoint tableau expects the stage at rho_k + 0.5*h; our stage is at
        # geometric mean -- adjust a21 so the stage STATE is advanced to the
        # actual stage rho (exact for the EI transfer):
        self._stage_frac = (stage_rho[:, 1] - rho[:-1]) / self.h

    def sample(self, eps_fn, x_T, key=None):
        n = len(self.ts) - 1
        dtype = x_T.dtype
        h = jnp.asarray(self.h, dtype)
        st_t = jnp.asarray(self.stage_t, dtype)
        st_mu = jnp.asarray(self.stage_mu, dtype)
        mu = jnp.asarray(self.mu, dtype)
        frac = jnp.asarray(self._stage_frac, dtype)

        def body(k, x):
            y = x / mu[k]
            k1 = eps_fn(st_mu[k, 0] * y, st_t[k, 0])
            y_mid = y + h[k] * frac[k] * k1
            k2 = eps_fn(st_mu[k, 1] * y_mid, st_t[k, 1])
            y = y + h[k] * k2
            return mu[k + 1] * y

        return jax.lax.fori_loop(0, n, body, x_T)


class EulerSolver(SolverBase):
    """Explicit Euler on the x-space PF-ODE (Eq. 7 with eps-parameterization)."""

    def __init__(self, sde: SDE, ts, name: str = "euler"):
        ts = _f64(ts)
        super().__init__(name, len(ts) - 1, sde, ts)
        self.f = _f64(sde.f(ts[:-1]))
        self.coef = 0.5 * _f64(sde.g2(ts[:-1])) / _f64(sde.sigma(ts[:-1]))
        self.dt = ts[1:] - ts[:-1]

    def sample(self, eps_fn, x_T, key=None):
        dtype = x_T.dtype
        f = jnp.asarray(self.f, dtype)
        coef = jnp.asarray(self.coef, dtype)
        dt = jnp.asarray(self.dt, dtype)
        t_arr = jnp.asarray(self.ts, dtype)

        def body(k, x):
            eps = eps_fn(x, t_arr[k])
            dx = f[k] * x + coef[k] * eps
            return x + dt[k] * dx

        return jax.lax.fori_loop(0, len(self.ts) - 1, body, x_T)


class EMSolver(SolverBase):
    """Euler-Maruyama on the lambda-SDE (Eq. 4); lambda=1 = reverse diffusion."""

    def __init__(self, sde: SDE, ts, lam: float = 1.0, name: str | None = None):
        ts = _f64(ts)
        super().__init__(name or f"em_lam{lam:g}", len(ts) - 1, sde, ts)
        self.lam = lam
        self.f = _f64(sde.f(ts[:-1]))
        self.coef = 0.5 * (1 + lam ** 2) * _f64(sde.g2(ts[:-1])) / _f64(sde.sigma(ts[:-1]))
        self.g = np.sqrt(_f64(sde.g2(ts[:-1])))
        self.dt = ts[1:] - ts[:-1]

    def sample(self, eps_fn, x_T, key=None):
        if key is None:
            raise ValueError("EMSolver requires a PRNG key")
        dtype = x_T.dtype
        f = jnp.asarray(self.f, dtype)
        coef = jnp.asarray(self.coef, dtype)
        g = jnp.asarray(self.g, dtype)
        dt = jnp.asarray(self.dt, dtype)
        t_arr = jnp.asarray(self.ts, dtype)
        lam = self.lam

        def body(k, carry):
            x, k_rng = carry
            k_rng, sub = jax.random.split(k_rng)
            eps = eps_fn(x, t_arr[k])
            drift = f[k] * x + coef[k] * eps
            noise = jax.random.normal(sub, x.shape, dtype)
            x = x + dt[k] * drift + lam * g[k] * jnp.sqrt(-dt[k]) * noise
            return x, k_rng

        x, _ = jax.lax.fori_loop(0, len(self.ts) - 1, body, (x_T, key))
        return x


class DDIMSolver(SolverBase):
    """Stochastic DDIM(eta) for VPSDE (Eq. 34; eta=0 == ABSolver order 0)."""

    def __init__(self, sde: VPSDE, ts, eta: float = 0.0, name: str | None = None):
        if not isinstance(sde, VPSDE):
            raise TypeError("stochastic DDIM is defined for VPSDE")
        ts = _f64(ts)
        super().__init__(name or f"ddim_eta{eta:g}", len(ts) - 1, sde, ts)
        ab = _f64(sde.alpha_bar(ts))
        self.eta = eta
        sig2 = (eta ** 2) * (1 - ab[1:]) / (1 - ab[:-1]) * (1 - ab[:-1] / ab[1:])
        sig2 = np.maximum(sig2, 0.0)
        self.a = np.sqrt(ab[1:] / ab[:-1])
        # x' = a x + b eps + s xi,  b = sqrt(1-ab'-sig2) - a sqrt(1-ab)
        self.b = np.sqrt(np.maximum(1 - ab[1:] - sig2, 0.0)) - self.a * np.sqrt(1 - ab[:-1])
        self.s = np.sqrt(sig2)

    def sample(self, eps_fn, x_T, key=None):
        if self.eta > 0 and key is None:
            raise ValueError("stochastic DDIM requires a PRNG key")
        dtype = x_T.dtype
        a = jnp.asarray(self.a, dtype)
        b = jnp.asarray(self.b, dtype)
        s = jnp.asarray(self.s, dtype)
        t_arr = jnp.asarray(self.ts, dtype)
        key = key if key is not None else jax.random.PRNGKey(0)

        def body(k, carry):
            x, k_rng = carry
            k_rng, sub = jax.random.split(k_rng)
            eps = eps_fn(x, t_arr[k])
            xi = jax.random.normal(sub, x.shape, dtype)
            return a[k] * x + b[k] * eps + s[k] * xi, k_rng

        x, _ = jax.lax.fori_loop(0, len(self.ts) - 1, body, (x_T, key))
        return x


class IPNDMSolver(SolverBase):
    """Improved PNDM (paper App. H.2, Algo 4): classical uniform-grid AB
    weights on the eps history, with lower-order warmup, + DDIM transfer."""

    def __init__(self, sde: SDE, ts, order: int = 3, name: str | None = None):
        ts = _f64(ts)
        super().__init__(name or f"ipndm{order}", len(ts) - 1, sde, ts)
        self.order = order
        psi, C0 = C.ab_coefficients(sde, ts, 0, "t")
        self.psi, self.C0 = psi, C0[:, 0]
        # per-step fixed AB weights with warmup, shape (N, order+1)
        n = len(ts) - 1
        W = np.zeros((n, order + 1))
        for k in range(n):
            r_eff = min(order, k)
            W[k, : r_eff + 1] = C.AB_WEIGHTS[r_eff]
        self.W = W

    def sample(self, eps_fn, x_T, key=None):
        dtype = x_T.dtype
        psi = jnp.asarray(self.psi, dtype)
        C0 = jnp.asarray(self.C0, dtype)
        W = jnp.asarray(self.W, dtype)
        t_arr = jnp.asarray(self.ts, dtype)
        order = self.order

        def body(k, carry):
            x, hist = carry
            eps = eps_fn(x, t_arr[k])
            hist = jnp.concatenate([eps[None], hist[:-1]], axis=0)
            eps_hat = jnp.tensordot(W[k], hist, axes=1)
            return psi[k] * x + C0[k] * eps_hat, hist

        hist0 = jnp.zeros((order + 1,) + x_T.shape, dtype)
        x, _ = jax.lax.fori_loop(0, len(self.ts) - 1, body, (x_T, hist0))
        return x


class PNDMSolver(SolverBase):
    """Original PNDM (Liu et al. 2022): pseudo-RK4 warmup for the first 3 steps
    (4 NFE each) then 4th-order AB with DDIM transfer. NFE = N + 9."""

    def __init__(self, sde: SDE, ts, name: str = "pndm"):
        ts = _f64(ts)
        if len(ts) - 1 < 4:
            raise ValueError("PNDM needs at least 4 steps")
        super().__init__(name, (len(ts) - 1) + 9, sde, ts)
        self.mu = _f64(sde.mu(ts))
        self.rho = _f64(sde.rho(ts))
        # warmup midpoints in t
        tm = 0.5 * (ts[:-1] + ts[1:])
        self.mu_mid = _f64(sde.mu(tm))
        self.rho_mid = _f64(sde.rho(tm))
        self.t_mid = tm
        psi, C0 = C.ab_coefficients(sde, ts, 0, "t")
        self.psi, self.C0 = psi, C0[:, 0]

    def _transfer(self, x, eps, mu_s, rho_s, mu_t, rho_t):
        """F_DDIM (Eq. 22 generalized): x' = (mu_t/mu_s) x + mu_t (rho_t - rho_s) eps."""
        return (mu_t / mu_s) * x + mu_t * (rho_t - rho_s) * eps

    def sample(self, eps_fn, x_T, key=None):
        dtype = x_T.dtype
        ts = self.ts
        mu, rho = self.mu, self.rho
        n = len(ts) - 1
        hist = []
        x = x_T
        for k in range(min(3, n)):  # pseudo-RK4 warmup (python unrolled; n static)
            t_c, t_m, t_n = ts[k], self.t_mid[k], ts[k + 1]
            m_c, r_c = mu[k], rho[k]
            m_m, r_m = self.mu_mid[k], self.rho_mid[k]
            m_n, r_n = mu[k + 1], rho[k + 1]
            e1 = eps_fn(x, jnp.asarray(t_c, dtype))
            x1 = self._transfer(x, e1, m_c, r_c, m_m, r_m)
            e2 = eps_fn(x1, jnp.asarray(t_m, dtype))
            x2 = self._transfer(x, e2, m_c, r_c, m_m, r_m)
            e3 = eps_fn(x2, jnp.asarray(t_m, dtype))
            x3 = self._transfer(x, e3, m_c, r_c, m_n, r_n)
            e4 = eps_fn(x3, jnp.asarray(t_n, dtype))
            e_prime = (e1 + 2 * e2 + 2 * e3 + e4) / 6.0
            x = self._transfer(x, e_prime, m_c, r_c, m_n, r_n)
            hist = [e1] + hist
            hist = hist[:4]
        w4 = C.AB_WEIGHTS[3]
        for k in range(min(3, n), n):
            e = eps_fn(x, jnp.asarray(ts[k], dtype))
            hist = [e] + hist[:3]
            e_hat = sum(float(w4[j]) * hist[j] for j in range(4))
            x = self.psi[k] * x + self.C0[k] * e_hat
        return x


def make_solver(name: str, sde: SDE, ts, **kw) -> SolverBase:
    """Factory. Names: ddim, tab{0..3}, rhoab{0..3}, rho_heun, rho_midpoint,
    rho_kutta3, rho_rk4, euler, naive_ei, em, ddim_eta, ipndm{1..3}, pndm."""
    n = name.lower()
    if n == "ddim" or n == "tab0" or n == "rhoab0":
        return ABSolver(sde, ts, order=0, basis="t", name=name)
    if n.startswith("tab"):
        return ABSolver(sde, ts, order=int(n[3:]), basis="t", name=name)
    if n.startswith("rhoab"):
        return ABSolver(sde, ts, order=int(n[5:]), basis="rho", name=name)
    if n.startswith("rho_"):
        return RKSolver(sde, ts, method=n[4:], name=name)
    if n == "dpm2":
        return DPMSolver2(sde, ts)
    if n == "euler":
        return EulerSolver(sde, ts)
    if n == "naive_ei":
        return ABSolver(sde, ts, order=0, naive_ei=True, name=name)
    if n == "em":
        return EMSolver(sde, ts, lam=kw.get("lam", 1.0))
    if n == "ddim_eta":
        return DDIMSolver(sde, ts, eta=kw.get("eta", 1.0))
    if n.startswith("ipndm"):
        order = int(n[5:]) if len(n) > 5 else 3
        return IPNDMSolver(sde, ts, order=order, name=name)
    if n == "pndm":
        return PNDMSolver(sde, ts)
    raise ValueError(f"unknown solver {name!r}")


SOLVER_NAMES = ["ddim", "tab1", "tab2", "tab3", "rhoab1", "rhoab2", "rhoab3",
                "rho_heun", "rho_midpoint", "rho_kutta3", "rho_rk4", "dpm2",
                "euler", "naive_ei", "em", "ddim_eta", "ipndm1", "ipndm2",
                "ipndm3", "pndm"]
