"""Legacy class-based solver API -- thin deprecation shims over SolverPlans.

.. deprecated::
    The class-per-solver API is superseded by the functional plan/step API:

        from repro.core import make_plan, sample
        plan = make_plan("tab3", sde, ts)          # pure builder, pytree out
        x0 = sample(plan, eps_fn, x_T)             # single jit/vmap-able executor

    Every class below now just builds its :class:`~repro.core.plan.SolverPlan`
    in ``__init__`` and delegates ``sample`` to
    :func:`repro.core.sampler.sample`, so outputs are identical between the
    two APIs by construction. New code (serving, benchmarks, anything that
    wants per-step streaming, mid-solve resume, vmap over requests, or shared
    jit executors) should use plans directly; see ``repro/core/plan.py``.

Migration map (old -> new):

    ABSolver(sde, ts, order, basis)    -> plan_ab(sde, ts, order, basis)
    ABSolver(..., fused_update=True)   -> plan_ab(..., fused=True)
    RKSolver(sde, ts, method)          -> plan_rk(sde, ts, method)
    DPMSolver2(sde, ts)                -> plan_rk(sde, ts, method="dpm2")
    EulerSolver(sde, ts)               -> plan_euler(sde, ts)
    EMSolver(sde, ts, lam)             -> plan_em(sde, ts, lam)
    DDIMSolver(sde, ts, eta)           -> plan_ddim(sde, ts, eta)
    IPNDMSolver(sde, ts, order)        -> plan_ipndm(sde, ts, order)
    PNDMSolver(sde, ts)                -> plan_pndm(sde, ts)
    make_solver(name, sde, ts).sample  -> sample(make_plan(name, sde, ts), ...)

The solver family itself is unchanged (paper Secs. 3-4, App. H.2): tAB/rhoAB-
DEIS (r=0 == deterministic DDIM, Prop. 2), rhoRK-DEIS (heun == EDM/Karras,
midpoint ~ DPM-Solver2), Euler, Euler-Maruyama on the lambda-SDE, stochastic
DDIM(eta) (Prop. 4), iPNDM and PNDM.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np

from . import plan as P
from . import sampler as S
from .plan import _TABLEAUS  # re-export: likelihood.py builds RK grids from it
from .sde import SDE, VPSDE

Array = jax.Array
EpsFn = Callable[[Array, Array], Array]


def _f64(x):
    return np.asarray(x, dtype=np.float64)


@dataclasses.dataclass
class SolverBase:
    """Deprecated shim base: holds a SolverPlan and delegates sampling."""

    name: str
    nfe: int
    sde: SDE
    ts: np.ndarray

    plan: Optional[P.SolverPlan] = dataclasses.field(default=None, repr=False)

    def sample(self, eps_fn: EpsFn, x_T: Array, key: Optional[Array] = None) -> Array:
        if self.plan is None:
            raise NotImplementedError
        return S.sample(self.plan, eps_fn, x_T, key)


class ABSolver(SolverBase):
    """Shim for tAB/rhoAB-DEIS (r=0 is DDIM); see :func:`repro.core.plan.plan_ab`."""

    def __init__(self, sde: SDE, ts, order: int = 0, basis: str = "t",
                 name: str | None = None, naive_ei: bool = False,
                 fused_update: bool = False):
        ts = _f64(ts)
        super().__init__(name or f"{basis}AB{order}", len(ts) - 1, sde, ts,
                         P.plan_ab(sde, ts, order=order, basis=basis,
                                   naive_ei=naive_ei, fused=fused_update))
        self.order = order
        self.fused_update = fused_update


class RKSolver(SolverBase):
    """Shim for rhoRK-DEIS; see :func:`repro.core.plan.plan_rk`."""

    def __init__(self, sde: SDE, ts, method: str = "heun", name: str | None = None):
        ts = _f64(ts)
        plan = P.plan_rk(sde, ts, method=method)
        super().__init__(name or f"rho_{method}", plan.nfe, sde, ts, plan)
        self.method = method


class DPMSolver2(RKSolver):
    """Shim for DPM-Solver-2 (Lu et al. 2022) == plan_rk(method="dpm2")."""

    def __init__(self, sde: SDE, ts, name: str = "dpm2"):
        super().__init__(sde, ts, method="dpm2", name=name)


class EulerSolver(SolverBase):
    """Shim for Euler on the x-space PF-ODE; see :func:`plan_euler`."""

    def __init__(self, sde: SDE, ts, name: str = "euler"):
        ts = _f64(ts)
        super().__init__(name, len(ts) - 1, sde, ts, P.plan_euler(sde, ts))


class EMSolver(SolverBase):
    """Shim for Euler-Maruyama on the lambda-SDE; see :func:`plan_em`."""

    def __init__(self, sde: SDE, ts, lam: float = 1.0, name: str | None = None):
        ts = _f64(ts)
        super().__init__(name or f"em_lam{lam:g}", len(ts) - 1, sde, ts,
                         P.plan_em(sde, ts, lam=lam))
        self.lam = lam

    def sample(self, eps_fn, x_T, key=None):
        if key is None:
            raise ValueError("EMSolver requires a PRNG key")
        return super().sample(eps_fn, x_T, key)


class DDIMSolver(SolverBase):
    """Shim for stochastic DDIM(eta); see :func:`plan_ddim`."""

    def __init__(self, sde: VPSDE, ts, eta: float = 0.0, name: str | None = None):
        ts = _f64(ts)
        super().__init__(name or f"ddim_eta{eta:g}", len(ts) - 1, sde, ts,
                         P.plan_ddim(sde, ts, eta=eta))
        self.eta = eta

    def sample(self, eps_fn, x_T, key=None):
        if self.eta > 0 and key is None:
            raise ValueError("stochastic DDIM requires a PRNG key")
        return super().sample(eps_fn, x_T, key)


class IPNDMSolver(SolverBase):
    """Shim for improved PNDM; see :func:`plan_ipndm`."""

    def __init__(self, sde: SDE, ts, order: int = 3, name: str | None = None):
        ts = _f64(ts)
        super().__init__(name or f"ipndm{order}", len(ts) - 1, sde, ts,
                         P.plan_ipndm(sde, ts, order=order))
        self.order = order


class PNDMSolver(SolverBase):
    """Shim for original PNDM (NFE = N + 9); see :func:`plan_pndm`."""

    def __init__(self, sde: SDE, ts, name: str = "pndm"):
        ts = _f64(ts)
        plan = P.plan_pndm(sde, ts)
        super().__init__(name, plan.nfe, sde, ts, plan)


def make_solver(name: str, sde: SDE, ts, **kw) -> SolverBase:
    """Deprecated factory (prefer :func:`repro.core.plan.make_plan`).

    Names: ddim, tab{0..3}, rhoab{0..3}, rho_heun, rho_midpoint, rho_kutta3,
    rho_rk4, dpm2, euler, naive_ei, em, ddim_eta (requires explicit ``eta=``),
    ipndm{1..3}, pndm.
    """
    n = name.lower()
    if n in ("ddim", "tab0", "rhoab0"):
        return ABSolver(sde, ts, order=0, basis="t", name=name)
    if n.startswith("tab"):
        return ABSolver(sde, ts, order=int(n[3:]), basis="t", name=name,
                        fused_update=kw.get("fused_update", False))
    if n.startswith("rhoab"):
        return ABSolver(sde, ts, order=int(n[5:]), basis="rho", name=name,
                        fused_update=kw.get("fused_update", False))
    if n.startswith("rho_"):
        return RKSolver(sde, ts, method=n[4:], name=name)
    if n == "dpm2":
        return DPMSolver2(sde, ts)
    if n == "euler":
        return EulerSolver(sde, ts)
    if n == "naive_ei":
        return ABSolver(sde, ts, order=0, naive_ei=True, name=name)
    if n == "em":
        return EMSolver(sde, ts, lam=kw.get("lam", 1.0))
    if n == "ddim_eta":
        if "eta" not in kw:
            raise TypeError(
                "make_solver('ddim_eta') requires an explicit eta= "
                "(eta=0 is deterministic DDIM, eta=1 ancestral sampling); "
                "the old silent eta=1.0 default conflicted with DDIMSolver's "
                "eta=0.0 default")
        return DDIMSolver(sde, ts, eta=kw["eta"])
    if n.startswith("ipndm"):
        order = int(n[5:]) if len(n) > 5 else 3
        return IPNDMSolver(sde, ts, order=order, name=name)
    if n == "pndm":
        return PNDMSolver(sde, ts)
    raise ValueError(f"unknown solver {name!r}")


SOLVER_NAMES = ["ddim", "tab1", "tab2", "tab3", "rhoab1", "rhoab2", "rhoab3",
                "rho_heun", "rho_midpoint", "rho_kutta3", "rho_rk4", "dpm2",
                "euler", "naive_ei", "em", "ddim_eta", "ipndm1", "ipndm2",
                "ipndm3", "pndm"]
