"""Deprecated name-based solver factory (the class shims are gone).

.. deprecated::
    The class-per-solver API (``ABSolver``, ``RKSolver``, ``DDIMSolver`` ...)
    has been removed: nothing internal imported it any more, and every solver
    is a pure :class:`~repro.core.plan.SolverPlan` applied by the single
    executor in :mod:`repro.core.sampler`:

        from repro.core import make_plan, sample
        plan = make_plan("tab3", sde, ts)          # pure builder, pytree out
        x0 = sample(plan, eps_fn, x_T)             # single jit/vmap-able executor

    ``make_solver`` survives as a thin alias that warns and returns the
    :class:`SolverPlan` ``make_plan`` would build (``fused_update=`` is
    translated to ``fused=`` for old call sites). Plans carry ``.nfe`` but no
    ``.sample`` method -- pass them to :func:`repro.core.sampler.sample`.

Migration map (old -> new):

    ABSolver(sde, ts, order, basis)    -> plan_ab(sde, ts, order, basis)
    ABSolver(..., fused_update=True)   -> plan_ab(..., fused=True)
    RKSolver(sde, ts, method)          -> plan_rk(sde, ts, method)
    DPMSolver2(sde, ts)                -> plan_rk(sde, ts, method="dpm2")
    EulerSolver(sde, ts)               -> plan_euler(sde, ts)
    EMSolver(sde, ts, lam)             -> plan_em(sde, ts, lam)
    DDIMSolver(sde, ts, eta)           -> plan_ddim(sde, ts, eta)
    IPNDMSolver(sde, ts, order)        -> plan_ipndm(sde, ts, order)
    PNDMSolver(sde, ts)                -> plan_pndm(sde, ts)
    make_solver(name, sde, ts).sample  -> sample(make_plan(name, sde, ts), ...)
    AdaptiveRK23 (analysis tool)       -> unchanged, repro.core.adaptive
"""
from __future__ import annotations

import warnings

from .plan import SolverPlan, make_plan
from .sde import SDE


def make_solver(name: str, sde: SDE, ts, **kw) -> SolverPlan:
    """Deprecated alias for :func:`repro.core.plan.make_plan`.

    Returns the ``SolverPlan`` directly (the class shims are gone); sample
    with ``repro.core.sample(plan, eps_fn, x_T, key)``. The legacy
    ``fused_update=`` keyword maps to the plan builders' ``fused=``.
    """
    warnings.warn(
        "make_solver is deprecated: build plans with repro.core.make_plan "
        "and run them with repro.core.sample/step",
        DeprecationWarning, stacklevel=2)
    if "fused_update" in kw:
        kw["fused"] = kw.pop("fused_update")
    return make_plan(name, sde, ts, **kw)


SOLVER_NAMES = ["ddim", "tab1", "tab2", "tab3", "rhoab1", "rhoab2", "rhoab3",
                "rho_heun", "rho_midpoint", "rho_kutta3", "rho_rk4", "dpm2",
                "euler", "naive_ei", "em", "ddim_eta", "ipndm1", "ipndm2",
                "ipndm3", "pndm",
                "dpm2m", "dpm3m", "seeds1", "seeds2", "seeds3",
                "scire2", "scire3", "sndeis1", "sndeis2", "sndeis3"]
