"""Data pipeline: deterministic synthetic corpora + sharded host->device feed.

Real-pipeline structure (index-based shards, per-host slicing, prefetch)
over synthetic sources so everything runs offline:

  * ``MarkovTextSource`` -- an order-1 Markov chain over the vocab with a
    banded transition kernel: non-trivial, learnable statistics (bigram
    structure) so training loss visibly decreases; seeded and reproducible.
  * ``frames``/``prefix`` stubs for audio/VLM frontends (the one allowed stub).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig


@dataclasses.dataclass
class MarkovTextSource:
    vocab_size: int
    seed: int = 0
    band: int = 16

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        self._starts = rng.randint(0, self.vocab_size, size=4096)

    def batch(self, step: int, batch: int, seq: int) -> np.ndarray:
        """Deterministic (step-indexed) batch of token ids (batch, seq)."""
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % 2 ** 31)
        v = self.vocab_size
        tok = np.empty((batch, seq), np.int64)
        tok[:, 0] = self._starts[rng.randint(0, len(self._starts), batch)]
        steps = rng.randint(1, self.band, size=(batch, seq - 1))
        sign = rng.choice([-1, 1], size=(batch, seq - 1))
        jump = rng.random((batch, seq - 1)) < 0.05
        rand_tok = rng.randint(0, v, size=(batch, seq - 1))
        for i in range(1, seq):
            nxt = (tok[:, i - 1] + sign[:, i - 1] * steps[:, i - 1]) % v
            tok[:, i] = np.where(jump[:, i - 1], rand_tok[:, i - 1], nxt)
        return tok.astype(np.int32)


def make_batch(cfg: ModelConfig, source: MarkovTextSource, step: int,
               batch: int, seq: int, np_dtype=np.float32) -> dict:
    """Full input batch for the arch (tokens + frontend stubs)."""
    out = {"tokens": source.batch(step, batch, seq)}
    rng = np.random.RandomState(step + 17)
    if cfg.arch_type == "encdec":
        out["frames"] = rng.randn(batch, cfg.encoder_seq, cfg.d_model).astype(np_dtype)
    if cfg.arch_type == "vlm":
        out["prefix"] = rng.randn(batch, cfg.prefix_tokens, cfg.d_model).astype(np_dtype)
    return out


def host_iterator(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                  start_step: int = 0) -> Iterator[dict]:
    src = MarkovTextSource(cfg.vocab_size, seed)
    step = start_step
    while True:
        yield make_batch(cfg, src, step, batch, seq)
        step += 1


def device_put_sharded(batch: dict, sharding) -> dict:
    """Place a host batch with the given (dict of) shardings."""
    if not isinstance(sharding, dict):
        sharding = {k: sharding for k in batch}
    return {k: jax.device_put(v, sharding[k]) for k, v in batch.items()}
