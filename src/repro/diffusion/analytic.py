"""Analytic score oracles (zero fitting error) for controlled experiments.

The paper separates *fitting error* from *discretization error* (Sec. 3). These
oracles give exact eps(x, t) so discretization error can be measured in
isolation -- the basis of our convergence-order validation:

  - Gaussian data N(m, diag(v)): p_t is Gaussian; moreover the PF-ODE solution
    is available in closed form (the flow is the quantile map
    x_t = mu_t m + s_t z with s_t^2 = mu_t^2 v + sigma_t^2), giving an *exact*
    ground truth x_0 for any x_T -- no reference solver needed.
  - Gaussian mixture: exact posterior-weighted score; reference x_0 from a
    fine-grid rho_rk4 solve.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sampler import bcast as _per_request
from ..core.sde import SDE


@dataclasses.dataclass
class GaussianData:
    """Data ~ N(mean, diag(var)). Exact eps and exact PF-ODE flow.

    ``eps_fn`` accepts a scalar ``t`` or a per-request vector ``t: (R,)``
    paired with ``x: (R, *inner)`` (the stacked-plan executor contract).
    """

    sde: SDE
    mean: np.ndarray
    var: np.ndarray

    def eps_fn(self):
        sde = self.sde
        m = jnp.asarray(self.mean)
        v = jnp.asarray(self.var)

        def eps(x, t):
            mu = _per_request(sde.mu(t), x)
            sig = _per_request(sde.sigma(t), x)
            marg_var = mu ** 2 * v + sig ** 2
            score = -(x - mu * m) / marg_var
            return -sig * score

        return eps

    def exact_flow(self, x_from, t_from: float, t_to: float):
        """Exact PF-ODE transport of x_from from t_from to t_to."""
        sde = self.sde
        m = jnp.asarray(self.mean)
        v = jnp.asarray(self.var)
        s = lambda t: jnp.sqrt(sde.mu(t) ** 2 * v + sde.sigma(t) ** 2)
        z = (x_from - sde.mu(t_from) * m) / s(t_from)
        return sde.mu(t_to) * m + s(t_to) * z


@dataclasses.dataclass
class GMMData:
    """Data ~ sum_i w_i N(m_i, var_i I) in R^D; exact score via posterior weights."""

    sde: SDE
    means: np.ndarray    # (K, D)
    variances: np.ndarray  # (K,)
    weights: np.ndarray  # (K,)

    def eps_fn(self):
        sde = self.sde
        means = jnp.asarray(self.means)
        variances = jnp.asarray(self.variances)
        logw = jnp.log(jnp.asarray(self.weights))
        d = means.shape[-1]

        def eps(x, t):
            mu, sig = sde.mu(t), sde.sigma(t)
            marg_var = mu ** 2 * variances + sig ** 2          # (K,)
            diff = x[..., None, :] - mu * means                 # (..., K, D)
            sq = jnp.sum(diff ** 2, -1)                         # (..., K)
            logp_k = logw - 0.5 * sq / marg_var - 0.5 * d * jnp.log(2 * jnp.pi * marg_var)
            post = jax.nn.softmax(logp_k, axis=-1)              # (..., K)
            score_k = -diff / marg_var[..., None]               # (..., K, D)
            score = jnp.sum(post[..., None] * score_k, axis=-2)
            return -sig * score

        return eps

    def sample_data(self, key, n: int):
        kc, kn = jax.random.split(key)
        comps = jax.random.choice(kc, len(self.weights), (n,), p=jnp.asarray(self.weights))
        noise = jax.random.normal(kn, (n, self.means.shape[-1]))
        m = jnp.asarray(self.means)[comps]
        s = jnp.sqrt(jnp.asarray(self.variances))[comps, None]
        return m + s * noise

    def log_prob(self, x):
        means = jnp.asarray(self.means)
        variances = jnp.asarray(self.variances)
        logw = jnp.log(jnp.asarray(self.weights))
        d = means.shape[-1]
        diff = x[..., None, :] - means
        sq = jnp.sum(diff ** 2, -1)
        logp_k = logw - 0.5 * sq / variances - 0.5 * d * jnp.log(2 * jnp.pi * variances)
        return jax.nn.logsumexp(logp_k, axis=-1)


def default_gmm(sde: SDE, d: int = 2, seed: int = 0) -> GMMData:
    """A well-separated 8-mode GMM in R^d (ring for d=2)."""
    rng = np.random.RandomState(seed)
    k = 8
    if d == 2:
        ang = np.linspace(0, 2 * np.pi, k, endpoint=False)
        means = 4.0 * np.stack([np.cos(ang), np.sin(ang)], -1)
    else:
        means = 4.0 * rng.randn(k, d)
    return GMMData(sde, means.astype(np.float64),
                   np.full((k,), 0.09), np.full((k,), 1.0 / k))
