"""Continuous diffusion language modeling: the paper's technique as a
first-class framework feature for every backbone in the zoo.

Tokens are embedded into R^{d_model}; a forward VPSDE noises the embeddings;
the backbone (bidirectional, time-conditioned) is trained as eps_theta via the
paper's Eq. 9 loss. Generation runs ANY DEIS solver in embedding space --
each NFE is one full-sequence backbone forward -- then rounds to tokens via
the LM head (Diffusion-LM-style anchor loss keeps embeddings decodable).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import sampler as SAMPLER
from ..core.plan import SolverPlan
from ..core.sde import SDE
from ..models import transformer as T

EMBED_SCALE = 1.0  # embeddings are ~N(0, 0.02^2) at init; rescale to unit-ish
X0_SCALE = 25.0    # x0 = embed * X0_SCALE so data std ~ 0.5


def token_embeddings(params, tokens):
    return params["embed"][tokens].astype(jnp.float32) * X0_SCALE


def diffusion_loss(params, cfg: ModelConfig, sde: SDE, tokens, key, *,
                   prefix=None, frames=None, ce_weight: float = 0.1,
                   remat: bool = False, unroll: int = 1, block_constraint=None):
    """Paper Eq. 9 (eps-matching, uniform weight) + rounding anchor CE + MoE aux."""
    b, s = tokens.shape
    k_t, k_eps = jax.random.split(key)
    t = jax.random.uniform(k_t, (b,), jnp.float32, sde.t0, sde.T)
    x0 = token_embeddings(params, tokens)
    eps = jax.random.normal(k_eps, x0.shape, jnp.float32)
    mu = sde.mu(t)[:, None, None]
    sig = sde.sigma(t)[:, None, None]
    xt = mu * x0 + sig * eps

    if cfg.arch_type == "vlm" and prefix is not None:
        xt = jnp.concatenate([prefix.astype(xt.dtype), xt], axis=1)
    out = T.forward(params, cfg, embeds=xt, t_cond=t, mode="train",
                    causal=False, frames=frames, remat=remat, unroll=unroll,
                    block_constraint=block_constraint)
    eps_pred = out["eps"].astype(jnp.float32)
    if cfg.arch_type == "vlm" and prefix is not None:
        eps_pred = eps_pred[:, prefix.shape[1]:]
    mse = jnp.mean(jnp.square(eps_pred - eps))

    # rounding anchor: decode x0_hat back to tokens through the LM head
    x0_hat = (xt[:, -s:] if cfg.arch_type == "vlm" else xt) - sig * eps_pred
    x0_hat = x0_hat / jnp.maximum(mu, 1e-4)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x0_hat / X0_SCALE) @ head.astype(jnp.float32)
    from ..training.steps import cross_entropy
    ce = cross_entropy(logits, tokens, cfg)

    aux = sum(out["aux"].values()) if out["aux"] else 0.0
    loss = mse + ce_weight * ce + aux
    return loss, {"loss": loss, "mse": mse, "ce": ce}


def make_eps_fn(params, cfg: ModelConfig, *, prefix=None, frames=None,
                use_pallas: bool = False, unroll: int = 1, valid_len=None):
    """eps_theta(x, t) closure for the DEIS solvers; x: (B, S, D), t scalar.

    ``valid_len``: optional (B,) int per-row true length for bucket-padded
    batches -- threaded to attention so a row's denoising trajectory does
    not depend on the bucketed tail padding."""
    def eps_fn(x, t):
        b = x.shape[0]
        t_b = jnp.broadcast_to(t, (b,)).astype(jnp.float32)
        xin = x
        vl = valid_len
        if cfg.arch_type == "vlm" and prefix is not None:
            xin = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
            if vl is not None:
                vl = vl + prefix.shape[1]   # prefix positions are all valid
        out = T.forward(params, cfg, embeds=xin, t_cond=t_b, mode="train",
                        causal=False, frames=frames, use_pallas=use_pallas,
                        unroll=unroll, valid_len=vl)
        eps = out["eps"].astype(x.dtype)
        if cfg.arch_type == "vlm" and prefix is not None:
            eps = eps[:, prefix.shape[1]:]
        return eps
    return eps_fn


def decode_tokens(params, cfg: ModelConfig, x0):
    """Round solved embeddings ``x0`` to tokens through the LM head.

    Shared by the one-shot sampler and the streaming serving engine (which
    decodes per-step partial states for streamed progress)."""
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x0 / X0_SCALE) @ head.astype(jnp.float32)
    return jnp.argmax(logits, -1)


def sample_tokens(params, cfg: ModelConfig, plan: SolverPlan, key,
                  *, batch: int, seq_len: int, prior_std: float | None = None,
                  prefix=None, frames=None, use_pallas: bool = False,
                  hooks=None):
    """Generate token sequences with a DEIS ``SolverPlan``. Returns (tokens, x0).

    A plan carries no SDE, so ``prior_std`` must be passed explicitly
    (``sde.prior_std()``). Jit-compatible with ``plan`` as a traced pytree
    argument, so one compiled executor serves every plan with the same
    signature at fixed (batch, seq_len).
    """
    if prior_std is None:
        raise TypeError("sample_tokens requires prior_std= (use "
                        "sde.prior_std(); a plan carries no SDE to recover "
                        "it from)")
    eps_fn = make_eps_fn(params, cfg, prefix=prefix, frames=frames,
                         use_pallas=use_pallas)
    k_prior, k_solve = jax.random.split(key)
    x_T = jax.random.normal(k_prior, (batch, seq_len, cfg.d_model), jnp.float32) \
        * prior_std
    x0 = SAMPLER.sample(plan, eps_fn, x_T, k_solve, hooks=hooks)
    return decode_tokens(params, cfg, x0), x0


# ----------------------------------------------- per-request-keyed streaming
def request_keys(seeds) -> jax.Array:
    """Stack per-request PRNG keys derived from each request's own seed.

    This is the per-request reproducibility contract: request ``i`` of a
    batch draws its prior and its solve noise from ``PRNGKey(seeds[i])``
    alone, so its sample is independent of which batch it landed in.
    """
    return jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])


def init_sample_state(cfg: ModelConfig, plan: SolverPlan, keys, *,
                      seq_len: int, prior_std: float, valid_lens=None):
    """Build the stacked ``SamplerState`` for a group of requests.

    ``plan`` must be a stacked plan (:func:`repro.core.plan.stack_plans`) and
    ``keys`` a ``(R, 2)`` stack from :func:`request_keys`. Each request's key
    is split into (prior, solve) exactly as the one-shot path splits its
    single key; the prior is drawn per request with shape ``(seq_len,
    d_model)`` so row ``i`` is bit-identical to a single-request solve.

    ``valid_lens``: optional sequence of per-row true lengths (<= seq_len)
    for bucket-padded groups. Row ``i``'s prior is drawn at its TRUE length
    and zero-padded to ``seq_len``, so the prior (and hence the whole
    deterministic trajectory, with attention masking the padded keys) is
    independent of which bucket the request landed in.
    """
    split = jax.vmap(jax.random.split)(keys)          # (R, 2, 2)
    k_prior, k_solve = split[:, 0], split[:, 1]
    if valid_lens is not None and any(int(v) != seq_len for v in valid_lens):
        rows = []
        for i, lv in enumerate(valid_lens):
            lv = int(lv)
            r = jax.random.normal(k_prior[i], (lv, cfg.d_model), jnp.float32)
            rows.append(jnp.pad(r, ((0, seq_len - lv), (0, 0))))
        x_T = jnp.stack(rows) * prior_std
    else:
        x_T = jax.vmap(
            lambda kk: jax.random.normal(kk, (seq_len, cfg.d_model), jnp.float32)
        )(k_prior) * prior_std
    return SAMPLER.init_state(plan, x_T, k_solve)


def sample_tokens_stream(params, cfg: ModelConfig, plan: SolverPlan, keys, *,
                         seq_len: int, prior_std: float, hooks=None):
    """One-shot solve of a stacked per-request-keyed group. Returns
    (tokens, x0).

    This is the reference the streaming engine must reproduce: running the
    same stacked plan step-by-step (interleaved with other groups) yields the
    same per-request samples, because each row's noise comes only from its
    own key chain."""
    eps_fn = make_eps_fn(params, cfg)
    state = init_sample_state(cfg, plan, keys, seq_len=seq_len,
                              prior_std=prior_std)
    x0 = SAMPLER.sample(plan, eps_fn, state.x, state.key, hooks=hooks)
    return decode_tokens(params, cfg, x0), x0
