"""Small trained score networks for the faithful-reproduction experiments.

The paper's checkpoints (CIFAR10 UNets) are unavailable offline; these stand
in as *real trained models with real fitting error*, which is what the paper's
analysis needs (Sec. 3.1: the learned score is inaccurate off-manifold). The
analytic GMM oracles isolate pure discretization error; these nets add the
fitting-error axis.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.sde import SDE
from ..models.layers import sinusoidal_embedding
from ..training.optimizer import AdamW, cosine_schedule


def init_mlp_score_net(key, data_dim: int, hidden: int = 128, depth: int = 3,
                       t_dim: int = 64):
    ks = jax.random.split(key, depth + 2)
    p = {"t_proj": jax.random.normal(ks[0], (t_dim, hidden)) * (1 / math.sqrt(t_dim))}
    dims = [data_dim + hidden] + [hidden] * depth
    p["layers"] = []
    for i in range(depth):
        p["layers"].append({
            "w": jax.random.normal(ks[i + 1], (dims[i], hidden)) * (1 / math.sqrt(dims[i])),
            "b": jnp.zeros((hidden,)),
        })
    p["out"] = {"w": jax.random.normal(ks[-1], (hidden, data_dim)) * 1e-3,
                "b": jnp.zeros((data_dim,))}
    return p


def mlp_score_apply(params, x, t, t_dim: int = 64):
    """x: (B, D); t scalar or (B,). Returns eps prediction (B, D)."""
    b = x.shape[0]
    t_b = jnp.broadcast_to(t, (b,)).astype(jnp.float32)
    te = sinusoidal_embedding(t_b, t_dim) @ params["t_proj"]
    h = jnp.concatenate([x, te], axis=-1)
    for layer in params["layers"]:
        h = jax.nn.silu(h @ layer["w"] + layer["b"])
    return h @ params["out"]["w"] + params["out"]["b"]


@dataclasses.dataclass
class TrainedScoreModel:
    params: dict
    sde: SDE
    t_dim: int = 64

    def eps_fn(self) -> Callable:
        params, t_dim = self.params, self.t_dim

        def eps(x, t):
            return mlp_score_apply(params, x, t, t_dim)

        return eps


def train_score_net(sde: SDE, data_fn, data_dim: int, *, steps: int = 2000,
                    batch: int = 512, lr: float = 1e-3, hidden: int = 128,
                    depth: int = 3, seed: int = 0,
                    log_every: int = 0) -> TrainedScoreModel:
    """Denoising score matching (paper Eq. 9, eps-parameterization, uniform
    weights). data_fn(key, n) -> (n, D) samples."""
    key = jax.random.PRNGKey(seed)
    params = init_mlp_score_net(key, data_dim, hidden, depth)
    opt = AdamW(cosine_schedule(lr, steps // 20, steps), weight_decay=1e-4)
    opt_state = opt.init(params)

    def loss_fn(p, x0, t, eps):
        mu = sde.mu(t)[:, None]
        sig = sde.sigma(t)[:, None]
        xt = mu * x0 + sig * eps
        pred = mlp_score_apply(p, xt, t)
        return jnp.mean(jnp.square(pred - eps))

    @jax.jit
    def step_fn(p, o, k):
        k1, k2, k3 = jax.random.split(k, 3)
        x0 = data_fn(k1, batch)
        t = jax.random.uniform(k2, (batch,), jnp.float32, sde.t0, sde.T)
        eps = jax.random.normal(k3, x0.shape)
        loss, grads = jax.value_and_grad(loss_fn)(p, x0, t, eps)
        p, o, _ = opt.update(grads, o, p)
        return p, o, loss

    for i in range(steps):
        key, sub = jax.random.split(key)
        params, opt_state, loss = step_fn(params, opt_state, sub)
        if log_every and i % log_every == 0:
            print(f"  score-net step {i}: loss {float(loss):.4f}")
    return TrainedScoreModel(params, sde)
