"""Fused DEIS multistep update kernel (paper Eq. 14).

    x' = psi * x + sum_{j<R} c_j * eps_hist[j]

The update is memory-bound (zero MXU work): the win over XLA's un-fused form
is reading x and each eps exactly once from HBM instead of R+1 round trips
for the partial sums. VPU-tiled: blocks are (BLK_M, 128)-aligned in VMEM;
scalars (psi, c_j) ride along as a small VMEM operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_M = 256
BLK_D = 128


def _kernel(scal_ref, x_ref, hist_ref, out_ref):
    # scal_ref: (R+1,) [psi, c_0..c_{R-1}]; x_ref: (BLK_M, BLK_D);
    # hist_ref: (R, BLK_M, BLK_D)
    psi = scal_ref[0]
    acc = psi.astype(jnp.float32) * x_ref[...].astype(jnp.float32)
    r = hist_ref.shape[0]
    for j in range(r):  # static unroll; R <= 4
        acc += scal_ref[1 + j].astype(jnp.float32) * hist_ref[j].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def default_interpret() -> bool:
    """Compiled by default; interpret only where Pallas cannot lower.

    Pallas lowers to Mosaic on TPU and Triton on GPU; only the CPU backend
    has no compiled lowering and must fall back to the Python interpreter.
    (The old default of ``interpret=True`` everywhere silently ran the
    "fused" kernel in interpret mode on accelerators, making it slower than
    the un-fused XLA form it exists to beat.)
    """
    return jax.default_backend() == "cpu"


def deis_step(x, eps_hist, psi, coeffs, *, interpret: bool | None = None):
    """x: (M, D); eps_hist: (R, M, D); psi scalar; coeffs: (R,).

    ``interpret=None`` resolves via :func:`default_interpret` at call time
    (compiled on TPU/GPU, interpreter on CPU); pass an explicit bool to
    force either mode (tests cross-check the two)."""
    if interpret is None:
        interpret = default_interpret()
    return _deis_step_jit(x, eps_hist, psi, coeffs, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _deis_step_jit(x, eps_hist, psi, coeffs, *, interpret: bool):
    m, d = x.shape
    r = eps_hist.shape[0]
    # pad to tile multiples
    pm = (-m) % BLK_M
    pd = (-d) % BLK_D
    xp = jnp.pad(x, ((0, pm), (0, pd)))
    hp = jnp.pad(eps_hist, ((0, 0), (0, pm), (0, pd)))
    scal = jnp.concatenate([jnp.reshape(psi, (1,)).astype(jnp.float32),
                            coeffs.astype(jnp.float32)])
    grid = ((m + pm) // BLK_M, (d + pd) // BLK_D)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r + 1,), lambda i, j: (0,)),
            pl.BlockSpec((BLK_M, BLK_D), lambda i, j: (i, j)),
            pl.BlockSpec((r, BLK_M, BLK_D), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((BLK_M, BLK_D), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(scal, xp, hp)
    return out[:m, :d]
