"""Fused DEIS multistep update kernel (paper Eq. 14), stacked-plan form.

    x'_row = psi_row * x_row + sum_{j<r} C_row[j] * eps_hist[j, row]
             (+ s_row * noise_row)                       [stochastic leaf]
    err_row = max_elem | sum_{j<r} E_row[j] * eps_hist[j, row] |   [error pair]

The update is memory-bound (zero MXU work): the win over XLA's un-fused form
is reading x and each eps exactly once from HBM instead of r+3 round trips
for the partial sums, the noise add and the error-pair combination. VPU-
tiled: blocks are (BLK_M, 128)-aligned in VMEM; per-row scalars (psi, C,
s, E) ride along as one small ``(R, ncols)`` VMEM operand indexed by the
row grid axis, which is what lets one kernel serve a stacked serving group
whose rows carry different solver coefficients.

The error output is an exact Linf: each block writes its partial
``max |E . hist|`` and the caller reduces with an outer ``jnp.max`` --
f32 max is reduction-order independent, so a row's error (and therefore
early-exit retirement) is bitwise identical between a solo solve (R=1) and
any stacked grouping of the same request.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import default_interpret as _resolve_interpret

BLK_M = 256
BLK_D = 128


def default_interpret() -> bool:
    """Compiled by default; interpret only where Pallas cannot lower.

    Resolved through the shared per-kernel capability table
    (:func:`repro.kernels.runtime.default_interpret`): Mosaic on TPU,
    Triton on GPU, interpreter on CPU only.
    """
    return _resolve_interpret("deis_step")


def _kernel(scal_ref, *refs, r, has_noise, has_err):
    # scal_ref: (1, ncols) f32 rows laid out [psi, C_0..C_{r-1}, s?, E_*?];
    # refs: x_ref (1,BM,BD), hist_ref (r,1,BM,BD), [noise_ref (1,BM,BD)],
    #       out_ref (1,BM,BD), [err_ref (1,1,1)]
    x_ref = refs[0]
    hist_ref = refs[1]
    noise_ref = refs[2] if has_noise else None
    out_idx = 3 if has_noise else 2
    out_ref = refs[out_idx]
    err_ref = refs[out_idx + 1] if has_err else None

    acc = scal_ref[0, 0] * x_ref[0].astype(jnp.float32)
    for j in range(r):  # static unroll; r <= 4
        acc += scal_ref[0, 1 + j] * hist_ref[j, 0].astype(jnp.float32)
    if has_noise:
        acc += scal_ref[0, 1 + r] * noise_ref[0].astype(jnp.float32)
    out_ref[0] = acc.astype(out_ref.dtype)

    if has_err:
        off = 1 + r + (1 if has_noise else 0)
        e = scal_ref[0, off] * hist_ref[0, 0].astype(jnp.float32)
        for j in range(1, r):
            e += scal_ref[0, off + j] * hist_ref[j, 0].astype(jnp.float32)
        err_ref[0, 0, 0] = jnp.max(jnp.abs(e))


@functools.partial(jax.jit, static_argnames=("has_err", "interpret"))
def _fused_ab_jit(scal, x, hist, noise, *, has_err: bool, interpret: bool):
    has_noise = noise is not None
    n_rows, m, d = x.shape
    r = hist.shape[0]
    ncols = scal.shape[1]
    # pad to tile multiples
    pm = (-m) % BLK_M
    pd = (-d) % BLK_D
    xp = jnp.pad(x, ((0, 0), (0, pm), (0, pd)))
    hp = jnp.pad(hist, ((0, 0), (0, 0), (0, pm), (0, pd)))
    nbm, nbd = (m + pm) // BLK_M, (d + pd) // BLK_D

    in_specs = [
        pl.BlockSpec((1, ncols), lambda g, i, j: (g, 0)),
        pl.BlockSpec((1, BLK_M, BLK_D), lambda g, i, j: (g, i, j)),
        pl.BlockSpec((r, 1, BLK_M, BLK_D), lambda g, i, j: (0, g, i, j)),
    ]
    operands = [scal, xp, hp]
    if has_noise:
        in_specs.append(pl.BlockSpec((1, BLK_M, BLK_D),
                                     lambda g, i, j: (g, i, j)))
        operands.append(jnp.pad(noise, ((0, 0), (0, pm), (0, pd))))
    out_specs = [pl.BlockSpec((1, BLK_M, BLK_D), lambda g, i, j: (g, i, j))]
    out_shape = [jax.ShapeDtypeStruct(xp.shape, x.dtype)]
    if has_err:
        out_specs.append(pl.BlockSpec((1, 1, 1), lambda g, i, j: (g, i, j)))
        out_shape.append(jax.ShapeDtypeStruct((n_rows, nbm, nbd),
                                              jnp.float32))

    res = pl.pallas_call(
        functools.partial(_kernel, r=r, has_noise=has_noise, has_err=has_err),
        grid=(n_rows, nbm, nbd),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    out = res[0][:, :m, :d]
    # exact Linf: per-block partial maxima reduced by an order-independent max
    err = jnp.max(res[1], axis=(1, 2)) if has_err else None
    return out, err


def fused_ab_step(x, hist, psi, coeffs, *, s=None, noise=None, err_coeffs=None,
                  interpret: bool | None = None):
    """One-HBM-round-trip stacked AB step.

    x: (R, M, D); hist: (r, R, M, D); psi: (R,); coeffs: (R, r).
    Optional stochastic leaf: s (R,) scales noise (R, M, D) (drawn by the
    caller -- PRNG semantics stay outside the kernel). Optional error pair:
    err_coeffs (R, r) yields err (R,) = per-row Linf of the embedded
    lower-order difference. Returns ``(x_new, err-or-None)``.

    ``interpret=None`` resolves via :func:`default_interpret` at call time
    (compiled on TPU/GPU, interpreter on CPU); pass an explicit bool to
    force either mode (tests cross-check the two).
    """
    if interpret is None:
        interpret = default_interpret()
    cols = [psi.astype(jnp.float32)[:, None], coeffs.astype(jnp.float32)]
    if noise is not None:
        cols.append(s.astype(jnp.float32)[:, None])
    if err_coeffs is not None:
        cols.append(err_coeffs.astype(jnp.float32))
    scal = jnp.concatenate(cols, axis=1)
    return _fused_ab_jit(scal, x, hist, noise,
                         has_err=err_coeffs is not None, interpret=interpret)


def deis_step(x, eps_hist, psi, coeffs, *, interpret: bool | None = None):
    """x: (M, D); eps_hist: (R, M, D); psi scalar; coeffs: (R,).

    Single-request deterministic form: one row of :func:`fused_ab_step`
    (the serving engine calls the stacked entry directly)."""
    if interpret is None:
        interpret = default_interpret()
    scal = jnp.concatenate([jnp.reshape(psi, (1, 1)).astype(jnp.float32),
                            coeffs.astype(jnp.float32)[None]], axis=1)
    out, _ = _fused_ab_jit(scal, x[None], eps_hist[:, None], None,
                           has_err=False, interpret=interpret)
    return out[0]
