"""Flash attention for TPU (blocked online-softmax), GQA + causal + SWA.

TPU-native design (not a CUDA port): the grid's minor-most dimension walks KV
blocks *sequentially* (TPU grids are sequential, unlike CUDA thread blocks),
so the running max/denominator live in VMEM scratch across grid steps --
no atomics, no shared-memory reductions. Q/K/V blocks are MXU-aligned
(BLK x head_dim). The GQA mapping h -> h // n_rep happens in the K/V
BlockSpec index maps, so kv heads are never materialized n_rep times in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, blk_q, blk_k, n_k_blocks, kv_len):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (BQ, D)
    k = k_ref[0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0].astype(jnp.float32)          # (BK, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = i * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = j * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = k_pos < kv_len                      # KV padding
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == n_k_blocks - 1)
    def _finish():
        out_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
                      ).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "blk_q", "blk_k",
                                    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = True):
    """q: (B,Sq,H,D); k,v: (B,Sk,KV,D), H % KV == 0. Returns (B,Sq,H,D).

    ``causal`` assumes q and k index the same positions (self-attention).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    assert h % kv == 0, (h, kv)
    n_rep = h // kv
    scale = 1.0 / math.sqrt(d)

    blk_q = min(blk_q, max(sq, 8))
    blk_k = min(blk_k, sk)
    pq = (-sq) % blk_q
    pk = (-sk) % blk_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pq, sk + pk
    n_k_blocks = sk_p // blk_k

    # fold (B, H) into one grid axis; head axis leaves the block
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq_p, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kv, sk_p, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kv, sk_p, d)

    def q_map(g, i, j):
        return (g, i, 0)

    def kv_map(g, i, j):
        return ((g // h) * kv + (g % h) // n_rep, j, 0)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, n_k_blocks=n_k_blocks, kv_len=sk)

    out = pl.pallas_call(
        kern,
        grid=(b * h, sq_p // blk_q, n_k_blocks),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), q_map),
            pl.BlockSpec((1, blk_k, d), kv_map),
            pl.BlockSpec((1, blk_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.reshape(b, h, sq_p, d).transpose(0, 2, 1, 3)
    return out[:, :sq]
