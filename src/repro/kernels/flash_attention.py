"""Flash attention (blocked online-softmax), GQA + causal + SWA, portable.

Written against the generic Pallas API so one kernel body lowers to Mosaic
on TPU and Triton on GPU: the grid is (batch*head, q-blocks) -- both axes
parallel-safe -- and the KV walk is an in-kernel ``fori_loop`` whose
running (max, denominator, accumulator) ride in the loop carry instead of
VMEM scratch carried across grid steps (TPU grids are sequential, CUDA
thread blocks are not, so cross-grid-step scratch is the one construct
that cannot port). Q blocks are MXU-aligned (BLK x head_dim). The GQA
mapping h -> h // n_rep happens in the K/V BlockSpec index maps, so kv
heads are never materialized n_rep times in HBM.

Cross-attention / KV-cache decode: query positions are offset by
``sk - sq`` so the LAST query aligns with the last key -- a 1-token decode
against a long cache attends (causally) to the whole prefix instead of
masking everything but ``k_pos == 0``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import default_interpret as _resolve_interpret

NEG_INF = -1e30


def default_interpret() -> bool:
    """Compiled by default; interpret only where Pallas cannot lower.

    Resolved through the shared per-kernel capability table
    (:func:`repro.kernels.runtime.default_interpret`).
    """
    return _resolve_interpret("flash_attention")


def _kernel(q_ref, k_ref, v_ref, out_ref, *, scale, causal, window,
            blk_k, n_k_blocks, kv_len, q_off):
    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)          # (BQ, D)
    blk_q, d = q.shape
    q_pos = q_off + i * blk_q + jax.lax.broadcasted_iota(
        jnp.int32, (blk_q, blk_k), 0)

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.ds(j * blk_k, blk_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(j * blk_k, blk_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = j * blk_k + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_k), 1)
        mask = k_pos < kv_len                  # KV padding
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    init = (jnp.full((blk_q,), NEG_INF, jnp.float32),
            jnp.zeros((blk_q,), jnp.float32),
            jnp.zeros((blk_q, d), jnp.float32))
    _, l, acc = jax.lax.fori_loop(0, n_k_blocks, body, init)
    out_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(out_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool | None = None):
    """q: (B,Sq,H,D); k,v: (B,Sk,KV,D), H % KV == 0. Returns (B,Sq,H,D).

    ``interpret=None`` resolves via :func:`default_interpret` at call time
    (compiled on TPU/GPU, interpreter on CPU); pass an explicit bool to
    force either mode (tests cross-check the two).
    """
    if interpret is None:
        interpret = default_interpret()
    return _flash_jit(q, k, v, causal=causal, window=window, blk_q=blk_q,
                      blk_k=blk_k, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "blk_q", "blk_k",
                                    "interpret"))
def _flash_jit(q, k, v, *, causal: bool, window: int, blk_q: int, blk_k: int,
               interpret: bool):
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    assert h % kv == 0, (h, kv)
    n_rep = h // kv
    scale = 1.0 / math.sqrt(d)

    blk_q = min(blk_q, max(sq, 8))
    blk_k = min(blk_k, sk)
    pq = (-sq) % blk_q
    pk = (-sk) % blk_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pq, sk + pk
    n_k_blocks = sk_p // blk_k

    # fold (B, H) into one grid axis; head axis leaves the block
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq_p, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * kv, sk_p, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * kv, sk_p, d)

    def q_map(g, i):
        return (g, i, 0)

    def kv_map(g, i):
        return ((g // h) * kv + (g % h) // n_rep, 0, 0)

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        blk_k=blk_k, n_k_blocks=n_k_blocks, kv_len=sk, q_off=sk - sq)

    out = pl.pallas_call(
        kern,
        grid=(b * h, sq_p // blk_q),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), q_map),
            pl.BlockSpec((1, sk_p, d), kv_map),
            pl.BlockSpec((1, sk_p, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    out = out.reshape(b, h, sq_p, d).transpose(0, 2, 1, 3)
    return out[:, :sq]
