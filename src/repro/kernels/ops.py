"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; interpret
mode executes the kernel body in Python for correctness validation) and False
on real TPU backends.
"""
from __future__ import annotations

import jax

from .deis_step import deis_step as _deis_step
from .flash_attention import flash_attention as _flash_attention
from .ssd_scan import ssd_scan as _ssd_scan


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def deis_step(x, eps_hist, psi, coeffs, *, interpret=None):
    return _deis_step(x, eps_hist, psi, coeffs,
                      interpret=_default_interpret() if interpret is None else interpret)


def flash_attention(q, k, v, *, causal=True, window=0, blk_q=128, blk_k=128,
                    interpret=None):
    return _flash_attention(
        q, k, v, causal=causal, window=window, blk_q=blk_q, blk_k=blk_k,
        interpret=_default_interpret() if interpret is None else interpret)


def ssd_scan(x, a, B, C, *, chunk=128, interpret=None):
    return _ssd_scan(x, a, B, C, chunk=chunk,
                     interpret=_default_interpret() if interpret is None else interpret)
