"""Public wrappers for the Pallas kernels.

``interpret=None`` resolves per kernel through the shared capability table
in :mod:`repro.kernels.runtime` (``default_interpret(kernel)``): compiled
wherever that kernel HAS a compiled lowering, interpreter otherwise. All
three kernels are now written against the generic Pallas API -- no
``pltpu`` scratch, no cross-grid-step state carry -- so all three lower to
Mosaic on TPU and Triton on GPU and interpret only on CPU.

(The history this layer guards against: ``deis_step`` once defaulted to
``interpret=True`` everywhere, then ``flash_attention``/``ssd_scan`` kept
the same literal default in their jitted signatures while this module
blanket-interpreted them off-TPU. RL005 lints the bug class; the capability
table is the single place the resolution lives.)
"""
from __future__ import annotations

from .deis_step import deis_step as _deis_step
from .deis_step import fused_ab_step as _fused_ab_step
from .flash_attention import flash_attention as _flash_attention
from .runtime import default_interpret  # noqa: F401  (re-export)
from .ssd_scan import ssd_scan as _ssd_scan


def deis_step(x, eps_hist, psi, coeffs, *, interpret=None):
    # interpret=None resolves inside the kernel via the capability table
    return _deis_step(x, eps_hist, psi, coeffs, interpret=interpret)


def fused_ab_step(x, hist, psi, coeffs, *, s=None, noise=None,
                  err_coeffs=None, interpret=None):
    # stacked serving entry: per-row [psi, C, s?, E?] + optional noise/err
    return _fused_ab_step(x, hist, psi, coeffs, s=s, noise=noise,
                          err_coeffs=err_coeffs, interpret=interpret)


def flash_attention(q, k, v, *, causal=True, window=0, blk_q=128, blk_k=128,
                    interpret=None):
    return _flash_attention(q, k, v, causal=causal, window=window,
                            blk_q=blk_q, blk_k=blk_k, interpret=interpret)


def ssd_scan(x, a, B, C, *, chunk=128, interpret=None):
    return _ssd_scan(x, a, B, C, chunk=chunk, interpret=interpret)
