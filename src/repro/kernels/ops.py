"""Jit'd public wrappers for the Pallas kernels.

``interpret=None`` resolves per kernel from the backend at call time,
compiled wherever that kernel HAS a compiled lowering:

* ``deis_step`` is written against the generic Pallas API, which lowers to
  Mosaic on TPU and Triton on GPU -- interpret mode only on CPU.
* ``flash_attention`` / ``ssd_scan`` use TPU-specific constructs (pltpu
  scratch shapes / memory spaces) with no Triton lowering -- compiled on
  TPU, interpret mode everywhere else.

The old shared default interpreted on every non-TPU backend, which silently
made the "fused" deis_step slower on GPU than the un-fused XLA form it
exists to beat.
"""
from __future__ import annotations

import jax

from .deis_step import deis_step as _deis_step
from .flash_attention import flash_attention as _flash_attention
from .ssd_scan import ssd_scan as _ssd_scan


def _tpu_only_interpret() -> bool:
    # for kernels whose compiled form is Mosaic-only: interpret off-TPU
    return jax.default_backend() != "tpu"


def deis_step(x, eps_hist, psi, coeffs, *, interpret=None):
    # interpret=None resolves inside the kernel (default_interpret():
    # compiled everywhere a lowering exists, interpret only on CPU)
    return _deis_step(x, eps_hist, psi, coeffs, interpret=interpret)


def flash_attention(q, k, v, *, causal=True, window=0, blk_q=128, blk_k=128,
                    interpret=None):
    return _flash_attention(
        q, k, v, causal=causal, window=window, blk_q=blk_q, blk_k=blk_k,
        interpret=_tpu_only_interpret() if interpret is None else interpret)


def ssd_scan(x, a, B, C, *, chunk=128, interpret=None):
    return _ssd_scan(x, a, B, C, chunk=chunk,
                     interpret=_tpu_only_interpret() if interpret is None else interpret)
