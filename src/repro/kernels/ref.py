"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def deis_step_ref(x, eps_hist, psi, coeffs):
    """x' = psi * x + sum_j coeffs[j] * eps_hist[j].

    x: (M, D); eps_hist: (R, M, D); psi scalar; coeffs (R,)."""
    comb = jnp.tensordot(coeffs.astype(jnp.float32),
                         eps_hist.astype(jnp.float32), axes=1)
    return (psi.astype(jnp.float32) * x.astype(jnp.float32) + comb).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,Sq,H,D); k,v: (B,Sk,KV,D) with H % KV == 0 (GQA)."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    n_rep = h // kv
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    # query positions offset so the LAST query aligns with the last key
    # (cross-attention / KV-cache decode with sq != sk)
    qp = (k.shape[1] - sq) + jnp.arange(sq)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(x, a, B, C):
    """Naive (exact) SSD recurrence oracle.

    x: (Bb,S,H,P), a: (Bb,S,H), B,C: (Bb,S,N).
    h_t = a_t h_{t-1} + B_t x_t^T ; y_t = C_t h_t. Returns (y, final_state)."""
    bb, s, h, p = x.shape
    n = B.shape[-1]

    def step(state, inp):
        x_t, a_t, b_t, c_t = inp
        state = state * a_t[:, :, None, None] + jnp.einsum(
            "bhp,bn->bhpn", x_t.astype(jnp.float32), b_t.astype(jnp.float32))
        y_t = jnp.einsum("bn,bhpn->bhp", c_t.astype(jnp.float32), state)
        return state, y_t

    init = jnp.zeros((bb, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(a, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
