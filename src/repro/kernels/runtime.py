"""Per-kernel backend capability: where each Pallas kernel has a compiled
lowering, and therefore what ``interpret=None`` should resolve to.

All three kernels are written against the generic Pallas API (no ``pltpu``
scratch shapes, no cross-grid-step state carry), which lowers to Mosaic on
TPU and Triton on GPU. Only the CPU backend has no compiled lowering and
must fall back to the Python interpreter. The table is per kernel so that a
future kernel with a narrower lowering (e.g. Mosaic-only constructs) can
declare it here instead of silently interpreting everywhere, which is the
bug class RL005 lints against.
"""
from __future__ import annotations

import jax

# kernel name -> backends with a compiled lowering for its Pallas form
_LOWERS: dict[str, tuple[str, ...]] = {
    "deis_step": ("tpu", "gpu", "cuda", "rocm"),
    "flash_attention": ("tpu", "gpu", "cuda", "rocm"),
    "ssd_scan": ("tpu", "gpu", "cuda", "rocm"),
}


def default_interpret(kernel: str = "deis_step") -> bool:
    """True when ``kernel`` has no compiled lowering on the active backend.

    This is what every kernel's ``interpret=None`` default resolves to at
    call time: compiled wherever a lowering exists, interpreter otherwise.
    (The old defaults -- ``interpret=True`` baked into jitted signatures,
    then a blanket "interpret off-TPU" -- silently ran kernels in interpret
    mode on backends that could compile them.)
    """
    try:
        lowers = _LOWERS[kernel]
    except KeyError:
        raise ValueError(
            f"unknown kernel {kernel!r}; known: {sorted(_LOWERS)}") from None
    return jax.default_backend() not in lowers
