"""Mamba2 SSD chunked-scan kernel (arXiv:2405.21060), TPU-native.

Per (batch, head) the grid walks chunks SEQUENTIALLY (minor grid dim); the
running state h in R^{P x N} lives in VMEM scratch across grid steps. Each
chunk does three MXU matmuls entirely in VMEM:

    scores = C B^T               (L x L)
    y_intra = (scores . decay . tril) x        (L x P)
    y_inter = (C decay_in) h_prev              (L x P)
    h_new   = a_chunk h_prev + (B . decay_out)^T x

This is the hardware adaptation of the paper's CUDA selective-scan: no warp
shuffles -- the sequential dependence is carried by the grid, the quadratic
within-chunk work feeds the systolic MXU, and the (L,L,H) decay tensor that
bloats the XLA path (see EXPERIMENTS.md §Perf jamba iteration) never leaves
VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, h_scr, *,
            n_chunks, chunk):
    cidx = pl.program_id(1)

    @pl.when(cidx == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)           # (L, P)
    a = a_ref[0, :, 0].astype(jnp.float32)     # (L,)
    B = b_ref[0].astype(jnp.float32)           # (L, N)
    C = c_ref[0].astype(jnp.float32)           # (L, N)

    log_a = jnp.log(jnp.maximum(a, 1e-37))
    cum = jnp.cumsum(log_a)                    # (L,) inclusive
    # within-chunk decay matrix exp(cum_t - cum_u) for u <= t
    seg = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * decay                         # (L, L)
    y = jax.lax.dot(w, x, preferred_element_type=jnp.float32)

    # inter-chunk from carried state
    h_prev = h_scr[...]                        # (P, N)
    c_in = C * jnp.exp(cum)[:, None]           # (L, N)
    y += jax.lax.dot_general(c_in, h_prev, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update
    decay_to_end = jnp.exp(cum[-1] - cum)      # (L,)
    b_out = B * decay_to_end[:, None]          # (L, N)
    h_new = h_prev * jnp.exp(cum[-1]) + jax.lax.dot_general(
        x, b_out, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h_scr[...] = h_new
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(cidx == n_chunks - 1)
    def _finish():
        state_out_ref[0] = h_new.astype(state_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, a, B, C, *, chunk: int = 128, interpret: bool = True):
    """x: (Bb,S,H,P); a: (Bb,S,H); B,C: (Bb,S,N). Returns (y, final_state).

    y: (Bb,S,H,P); final_state: (Bb,H,P,N) float32.
    """
    bb, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    n_chunks = sp // chunk

    # layouts: fold (B,H) -> G for x/a; B/C shared across heads (indexed by
    # batch only in the map)
    xt = x.transpose(0, 2, 1, 3).reshape(bb * h, sp, p)
    at = a.transpose(0, 2, 1).reshape(bb * h, sp, 1)

    # grid: (batch*head, chunks) -- chunks minor => sequential state carry
    def xa_map2(g, c):
        return (g, c, 0)

    def bc_map2(g, c):
        return (g // h, c, 0)

    kern = functools.partial(_kernel, n_chunks=n_chunks, chunk=chunk)
    y, state = pl.pallas_call(
        kern,
        grid=(bb * h, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, p), xa_map2),
            pl.BlockSpec((1, chunk, 1), xa_map2),
            pl.BlockSpec((1, chunk, n), bc_map2),
            pl.BlockSpec((1, chunk, n), bc_map2),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), xa_map2),
            pl.BlockSpec((1, p, n), lambda g, c: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb * h, sp, p), x.dtype),
            jax.ShapeDtypeStruct((bb * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, at, B, C)
    y = y.reshape(bb, h, sp, p).transpose(0, 2, 1, 3)[:, :s]
    state = state.reshape(bb, h, p, n)
    return y, state
