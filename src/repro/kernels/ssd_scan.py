"""Mamba2 SSD chunked-scan kernel (arXiv:2405.21060), portable Pallas.

Per (batch, head) grid instance the kernel walks chunks with an in-kernel
``fori_loop``; the running state h in R^{P x N} is the loop carry, not VMEM
scratch carried across grid steps (the grid axis is parallel-safe, so the
same body lowers to Mosaic on TPU and Triton on GPU). Each chunk does three
MXU matmuls entirely on-chip:

    scores = C B^T               (L x L)
    y_intra = (scores . decay . tril) x        (L x P)
    y_inter = (C decay_in) h_prev              (L x P)
    h_new   = a_chunk h_prev + (B . decay_out)^T x

This is the hardware adaptation of the paper's CUDA selective-scan: no warp
shuffles -- the sequential dependence is carried by the loop, the quadratic
within-chunk work feeds the systolic MXU, and the (L,L,H) decay tensor that
bloats the XLA path (see EXPERIMENTS.md §Perf jamba iteration) never leaves
VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import default_interpret as _resolve_interpret


def default_interpret() -> bool:
    """Compiled by default; interpret only where Pallas cannot lower.

    Resolved through the shared per-kernel capability table
    (:func:`repro.kernels.runtime.default_interpret`).
    """
    return _resolve_interpret("ssd_scan")


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, *,
            n_chunks, chunk):
    p = x_ref.shape[-1]
    n = b_ref.shape[-1]

    def body(cidx, h_prev):
        sl = pl.ds(cidx * chunk, chunk)
        x = x_ref[0, sl, :].astype(jnp.float32)        # (L, P)
        a = a_ref[0, sl, 0].astype(jnp.float32)        # (L,)
        B = b_ref[0, sl, :].astype(jnp.float32)        # (L, N)
        C = c_ref[0, sl, :].astype(jnp.float32)        # (L, N)

        log_a = jnp.log(jnp.maximum(a, 1e-37))
        cum = jnp.cumsum(log_a)                        # (L,) inclusive
        # within-chunk decay matrix exp(cum_t - cum_u) for u <= t
        seg = cum[:, None] - cum[None, :]
        tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1) <= \
            jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        decay = jnp.where(tri, jnp.exp(seg), 0.0)

        scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
        w = scores * decay                             # (L, L)
        y = jax.lax.dot(w, x, preferred_element_type=jnp.float32)

        # inter-chunk from carried state
        c_in = C * jnp.exp(cum)[:, None]               # (L, N)
        y += jax.lax.dot_general(c_in, h_prev, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)

        # state update
        decay_to_end = jnp.exp(cum[-1] - cum)          # (L,)
        b_out = B * decay_to_end[:, None]              # (L, N)
        h_new = h_prev * jnp.exp(cum[-1]) + jax.lax.dot_general(
            x, b_out, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        y_ref[0, sl, :] = y.astype(y_ref.dtype)
        return h_new

    h = jax.lax.fori_loop(0, n_chunks, body,
                          jnp.zeros((p, n), jnp.float32))
    state_out_ref[0] = h.astype(state_out_ref.dtype)


def ssd_scan(x, a, B, C, *, chunk: int = 128, interpret: bool | None = None):
    """x: (Bb,S,H,P); a: (Bb,S,H); B,C: (Bb,S,N). Returns (y, final_state).

    y: (Bb,S,H,P); final_state: (Bb,H,P,N) float32.

    ``interpret=None`` resolves via :func:`default_interpret` at call time
    (compiled on TPU/GPU, interpreter on CPU); pass an explicit bool to
    force either mode (tests cross-check the two).
    """
    if interpret is None:
        interpret = default_interpret()
    return _ssd_scan_jit(x, a, B, C, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_scan_jit(x, a, B, C, *, chunk: int, interpret: bool):
    bb, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    n_chunks = sp // chunk

    # layouts: fold (B,H) -> G for x/a; B/C shared across heads (indexed by
    # batch only in the map)
    xt = x.transpose(0, 2, 1, 3).reshape(bb * h, sp, p)
    at = a.transpose(0, 2, 1).reshape(bb * h, sp, 1)

    def xa_map(g):
        return (g, 0, 0)

    def bc_map(g):
        return (g // h, 0, 0)

    kern = functools.partial(_kernel, n_chunks=n_chunks, chunk=chunk)
    y, state = pl.pallas_call(
        kern,
        grid=(bb * h,),
        in_specs=[
            pl.BlockSpec((1, sp, p), xa_map),
            pl.BlockSpec((1, sp, 1), xa_map),
            pl.BlockSpec((1, sp, n), bc_map),
            pl.BlockSpec((1, sp, n), bc_map),
        ],
        out_specs=[
            pl.BlockSpec((1, sp, p), xa_map),
            pl.BlockSpec((1, p, n), xa_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb * h, sp, p), x.dtype),
            jax.ShapeDtypeStruct((bb * h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xt, at, B, C)
    y = y.reshape(bb, h, sp, p).transpose(0, 2, 1, 3)[:, :s]
    state = state.reshape(bb, h, p, n)
    return y, state
