import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) combo
on 512 placeholder CPU devices and extract roofline inputs.

MUST be the entrypoint process (XLA_FLAGS is set above before any jax import).

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma_2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import INPUT_SHAPES, ModelConfig, get_config
from ..core.sde import VPSDE
from ..models import transformer as T
from ..sharding import rules as R
from ..training.optimizer import AdamW, constant_schedule
from ..training import steps as STEPS
from .mesh import make_production_mesh, PEAK_FLOPS_BF16, HBM_BW, ICI_BW

# archs that may run the 524k-decode shape (sub-quadratic attention path);
# see DESIGN.md §Arch-applicability for the skip rationale.
LONG_OK = {"mamba2_2p7b", "jamba_1p5_large", "h2o_danube_3_4b", "mixtral_8x7b"}

ALL_ARCHS = ["whisper_tiny", "h2o_danube_3_4b", "paligemma_3b", "mixtral_8x7b",
             "grok_1_314b", "mamba2_2p7b", "glm4_9b", "gemma_2b",
             "granite_3_8b", "jamba_1p5_large"]

FSDP_PARAM_THRESHOLD = 8e9  # shard big-model weights/opt-state over 'data' too


def param_count(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def _sds(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def make_workload(cfg: ModelConfig, shape_name: str, mesh, *, fsdp=None,
                  remat=True, seq_shard_cache=True, sde=None, unroll=1,
                  ff2d=False, zero3=False, deis_shard="dmodel"):
    """Returns (fn, arg_specs, in_shardings, donate) for the given workload.

    zero3: FSDP weights are all-gathered per BLOCK inside the scan body
    (with_sharding_constraint to model-only specs) instead of letting GSPMD
    choose -- ZeRO-3 just-in-time gathering (§Perf grok iteration)."""
    shp = INPUT_SHAPES[shape_name]
    b, s = shp.global_batch, shp.seq_len
    dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
    sde = sde or VPSDE()

    params_shape = jax.eval_shape(lambda k: T.init_params(cfg, k),
                                  jax.ShapeDtypeStruct((2,), jnp.uint32))
    n_params = param_count(params_shape)
    if fsdp is None:
        fsdp = n_params > FSDP_PARAM_THRESHOLD
    pspec = R.param_specs(params_shape, mesh, fsdp=fsdp, ff2d=ff2d)
    psh = R.to_shardings(pspec, mesh)
    ba = R.batch_axes(mesh)

    block_constraint = None
    if zero3 and fsdp:
        # model-only specs for ONE block slice (leading stacked dim removed)
        slice_shape = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
            params_shape["blocks"])
        slice_spec = R.param_specs(slice_shape, mesh, fsdp=False)
        block_constraint = jax.tree.map(
            lambda sp: NamedSharding(mesh, sp), slice_spec,
            is_leaf=lambda x: isinstance(x, P))

    batch_shape = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.arch_type == "encdec":
        batch_shape["frames"] = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.d_model), dtype)
    if cfg.arch_type == "vlm":
        batch_shape["prefix"] = jax.ShapeDtypeStruct((b, cfg.prefix_tokens, cfg.d_model), dtype)
    bsh = R.to_shardings(R.batch_specs(batch_shape, mesh), mesh)

    if shp.kind == "train":
        opt = AdamW(constant_schedule(1e-4))
        opt_shape = jax.eval_shape(opt.init, params_shape)
        osh = R.to_shardings(R.opt_state_specs(opt_shape, pspec, mesh), mesh)
        fn = STEPS.make_train_step(cfg, opt, sde, remat=remat, unroll=unroll,
                                   block_constraint=block_constraint)
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        args = (params_shape, opt_shape, batch_shape, rng)
        in_sh = (psh, osh, bsh, NamedSharding(mesh, P()))
        donate = (0, 1)
        return fn, args, in_sh, donate, n_params

    if shp.kind == "prefill":
        fn = STEPS.make_prefill_step(cfg, unroll=unroll)
        args = (params_shape, batch_shape)
        return fn, args, (psh, bsh), (), n_params

    if shp.kind == "deis":
        # one DEIS NFE over a batch of embedding-space states (the paper's
        # sampling workload): eps eval + fused multistep update (Eq. 14)
        fn = STEPS.make_deis_sample_step(cfg, sde, unroll=unroll)
        order = 3
        x = jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)
        hist = jax.ShapeDtypeStruct((order + 1, b, s, cfg.d_model), dtype)
        scal = jax.ShapeDtypeStruct((), jnp.float32)
        coeff = jax.ShapeDtypeStruct((order + 1,), jnp.float32)
        t = jax.ShapeDtypeStruct((), jnp.float32)
        if deis_shard == "seq":
            xs = NamedSharding(mesh, P(ba, "model", None))
            hs = NamedSharding(mesh, P(None, ba, "model", None))
        else:
            xs = NamedSharding(mesh, P(ba, None, "model"))
            hs = NamedSharding(mesh, P(None, ba, None, "model"))
        rep = NamedSharding(mesh, P())
        args = (params_shape, x, hist, t, scal, coeff)
        return fn, args, (psh, xs, hs, rep, rep, rep), (1, 2), n_params

    # decode: ONE token against a seq_len cache
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, b, s, dtype))
    csh = R.to_shardings(R.cache_specs(cache_shape, mesh, seq_shard=seq_shard_cache), mesh)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tsh = NamedSharding(mesh, P(ba) if b % np.prod([mesh.shape[a] for a in ba]) == 0 else P())
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    fn = STEPS.make_decode_step(cfg, unroll=unroll)
    args = (params_shape, cache_shape, token, idx)
    in_sh = (psh, csh, tsh, NamedSharding(mesh, P()))
    return fn, args, in_sh, (1,), n_params


_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4, "s16": 2,
          "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives, parsed from post-SPMD HLO.

    Uses the RESULT shape of each collective op line; all-reduce counted 2x
    (ring reduce+broadcast), others 1x. Start/done pairs counted once.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", ls)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start)?\(", rhs):
                op = c
                break
        if op is None or f"{op}-done" in rhs:
            continue
        # result type is everything before the op name
        head = rhs.split(op)[0]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(head):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES.get(dt, 4)
        mult = 2.0 if op == "all-reduce" else 1.0
        out[op] += mult * nbytes
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE), D = tokens processed.
    Decode: D = global_batch (one token each); train counts fwd+bwd (x3)."""
    shp = INPUT_SHAPES[shape_name]
    params_shape = jax.eval_shape(lambda k: T.init_params(cfg, k),
                                  jax.ShapeDtypeStruct((2,), jnp.uint32))
    n_active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        ps = R._path_str(path)
        n = int(np.prod(leaf.shape))
        if cfg.moe is not None and re.search(r"moe/(w_up|w_gate|w_down)$", ps):
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        if re.search(r"^embed$", ps):
            if cfg.tie_embeddings:
                n_active += n  # used as the LM head matmul
            continue  # lookup itself is not a matmul
        n_active += n
    tokens = shp.global_batch * (shp.seq_len if shp.kind != "decode" else 1)
    mult = 6.0 if shp.kind == "train" else 2.0
    return mult * n_active * tokens


def _compile_costs(cfg, shape_name, mesh, *, fsdp, remat, seq_shard_cache,
                   unroll, ff2d=False, zero3=False, **wl_kw):
    """Compile one workload (possibly depth-reduced) and return cost terms."""
    fn, args, in_sh, donate, _ = make_workload(
        cfg, shape_name, mesh, fsdp=fsdp, remat=remat,
        seq_shard_cache=seq_shard_cache, unroll=unroll, ff2d=ff2d, zero3=zero3,
        **wl_kw)
    jfn = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
    with jax.set_mesh(mesh):
        compiled = jfn.lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collective": coll["total"],
            "coll_by_op": {k: coll[k] for k in _COLLECTIVES}}


def extrapolated_costs(cfg, shape_name, mesh, *, fsdp, remat,
                       seq_shard_cache, ff2d=False, zero3=False, **wl_kw) -> dict:
    """Depth-extrapolated per-device costs.

    XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
    count (verified in tests/test_dryrun_units.py), so a rolled lax.scan over
    n_blocks undercounts by ~n_blocks. Fully unrolling the 64-72 block configs
    is compile-time-prohibitive on this host, so: compile depth-1-block and
    depth-2-block versions UNROLLED (exact costs) and extrapolate linearly:

        cost(n) = cost(1) + (n - 1) * [cost(2) - cost(1)]

    Exact for anything linear in depth (per-block compute, per-block
    collectives, optimizer update) and for depth-constant terms (embedding,
    logits, encoder); blocks are homogeneous by construction.
    """
    from ..models.transformer import block_size as _bs, n_blocks as _nb
    nb = _nb(cfg)
    bs = _bs(cfg)
    if nb <= 2:
        c = _compile_costs(cfg, shape_name, mesh, fsdp=fsdp, remat=remat,
                           seq_shard_cache=seq_shard_cache, unroll=True,
                           ff2d=ff2d, zero3=zero3, **wl_kw)
        return dict(c, extrapolated=False)
    cfg1 = cfg.with_(n_layers=bs)
    cfg2 = cfg.with_(n_layers=2 * bs)
    c1 = _compile_costs(cfg1, shape_name, mesh, fsdp=fsdp, remat=remat,
                        seq_shard_cache=seq_shard_cache, unroll=True, ff2d=ff2d,
                        zero3=zero3, **wl_kw)
    c2 = _compile_costs(cfg2, shape_name, mesh, fsdp=fsdp, remat=remat,
                        seq_shard_cache=seq_shard_cache, unroll=True, ff2d=ff2d,
                        zero3=zero3, **wl_kw)
    def _extrap(a, b):
        # per-block slope clamped at >= 0: XLA occasionally optimizes the
        # 2-block module below the 1-block one (decode-path fusions); a
        # negative slope extrapolated 60+ blocks is nonsense, so floor it.
        body = max(0.0, b - a)
        return max(a + (nb - 1) * body, b)

    out = {}
    for k in ("flops", "bytes"):
        out[k] = _extrap(c1[k], c2[k])
    out["coll_by_op"] = {k: _extrap(c1["coll_by_op"][k], c2["coll_by_op"][k])
                         for k in _COLLECTIVES}
    out["collective"] = sum(out["coll_by_op"].values())
    out["raw_depth_costs"] = {"c1": {k: c1[k] for k in ("flops", "bytes", "collective")},
                              "c2": {k: c2[k] for k in ("flops", "bytes", "collective")}}
    out["extrapolated"] = True
    return out


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool, mesh=None,
               fsdp=None, remat=True, seq_shard_cache=True, objective=None,
               unroll=True, verbose=True, overrides: dict | None = None,
               ff2d: bool = False, zero3: bool = False, deis_shard="dmodel") -> dict:
    cfg = get_config(arch)
    shp = INPUT_SHAPES[shape_name]
    if objective is None:
        objective = "diffusion" if shp.kind in ("train", "deis") else "ar"
    cfg = cfg.with_(objective=objective)
    if shp.kind == "deis" and cfg.arch_type == "encdec":
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "deis sampling workload is lowered unconditionally; "
                          "enc-dec conditioning goes through the serve engine"}
    if overrides:
        import dataclasses as _dc
        ssm_over = {k[4:]: v for k, v in overrides.items() if k.startswith("ssm_")}
        plain = {k: v for k, v in overrides.items() if not k.startswith("ssm_")}
        if ssm_over and cfg.ssm is not None:
            cfg = cfg.with_(ssm=_dc.replace(cfg.ssm, **ssm_over))
        if plain:
            cfg = cfg.with_(**plain)
    if shape_name == "long_500k" and arch not in LONG_OK:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch; see DESIGN.md shape-coverage skips"}

    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.perf_counter()
    if fsdp is None:  # resolve from the FULL model so the depth-reduced
        # extrapolation compiles use the same sharding policy
        full_shape = jax.eval_shape(lambda k: T.init_params(cfg, k),
                                    jax.ShapeDtypeStruct((2,), jnp.uint32))
        fsdp = param_count(full_shape) > FSDP_PARAM_THRESHOLD
    # 1) full-depth rolled compile: THE lowering proof + memory analysis.
    # Whole-loss remat here: per-block remat makes GSPMD+MoE compiles
    # intractably slow at depth 64 (documented in EXPERIMENTS.md §Dry-run);
    # the extrapolation compiles below use per-block remat for honest costs.
    fn, args, in_sh, donate, n_params = make_workload(
        cfg, shape_name, mesh, fsdp=fsdp, remat=("loss" if remat else False),
        seq_shard_cache=seq_shard_cache, unroll=1, ff2d=ff2d, zero3=zero3,
        deis_shard=deis_shard)
    jfn = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
    with jax.set_mesh(mesh):
        lowered = jfn.lower(*args)
        compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # noqa: BLE001
        mem_info = {"error": str(e)}

    # 2) depth-extrapolated costs (exact loop-body accounting; see docstring)
    if unroll:
        costs = extrapolated_costs(cfg, shape_name, mesh, fsdp=fsdp,
                                   remat=("block" if remat else False),
                                   seq_shard_cache=seq_shard_cache, ff2d=ff2d,
                                   zero3=zero3, deis_shard=deis_shard)
    else:
        cost = compiled.cost_analysis() or {}
        coll0 = collective_bytes(compiled.as_text())
        costs = {"flops": float(cost.get("flops", 0.0)),
                 "bytes": float(cost.get("bytes accessed", 0.0)),
                 "collective": coll0["total"],
                 "coll_by_op": {k: coll0[k] for k in _COLLECTIVES},
                 "extrapolated": False}
    flops_dev, bytes_dev = costs["flops"], costs["bytes"]

    mf = model_flops(cfg, shape_name)
    compute_term = flops_dev / PEAK_FLOPS_BF16 if flops_dev > 0 else None
    memory_term = bytes_dev / HBM_BW if bytes_dev > 0 else None
    collective_term = costs["collective"] / ICI_BW
    terms = {"compute_s": compute_term, "memory_s": memory_term,
             "collective_s": collective_term}
    present = {k: v for k, v in terms.items() if v is not None}
    bottleneck = max(present, key=present.get) if present else None

    res = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": ("pod2x16x16" if multi_pod else "16x16"), "devices": n_dev,
        "objective": objective, "n_params": n_params,
        "compile_s": round(time.perf_counter() - t0, 1),
        "full_compile_s": round(compile_s, 1),
        "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
        "collectives": dict(costs["coll_by_op"], total=costs["collective"]),
        "cost_extrapolated": costs.get("extrapolated", False),
        "memory": mem_info,
        "roofline": terms, "bottleneck": bottleneck,
        "model_flops_total": mf,
        "useful_flops_ratio": (mf / (flops_dev * n_dev)
                               if flops_dev and flops_dev > 0 else None),
    }
    if verbose:
        print(json.dumps({k: res[k] for k in
                          ("arch", "shape", "mesh", "status", "compile_s",
                           "flops_per_device", "bytes_per_device", "bottleneck")}))
        print("  roofline:", terms)
        print("  collectives:", res["collectives"])
        print("  memory_analysis:", mem_info)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--objective", default=None)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep lax.scan rolled (faster compile; XLA cost "
                         "analysis then counts the loop body once)")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default=None, help="write JSONL results here")
    args = ap.parse_args()

    combos = []
    archs = ALL_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    results = []
    mesh_cache = {}
    for a, s, mp in combos:
        if mp not in mesh_cache:
            mesh_cache[mp] = make_production_mesh(multi_pod=mp)
        try:
            r = dryrun_one(a, s, multi_pod=mp, mesh=mesh_cache[mp],
                           fsdp=(False if args.no_fsdp else None),
                           remat=not args.no_remat, objective=args.objective,
                           unroll=not args.no_unroll)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            r = {"arch": a, "shape": s,
                 "mesh": ("pod2x16x16" if mp else "16x16"),
                 "status": "error", "error": str(e)[:2000]}
        results.append(r)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(r) + "\n")

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {n_err} errors"
          f" / {len(results)} combos")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
