import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimb driver: run a (arch x shape) pair's baseline and a series
of named variants through the dry-run cost extraction and print the deltas.

  PYTHONPATH=src python -m repro.launch.hillclimb --pair grok_train
  PYTHONPATH=src python -m repro.launch.hillclimb --pair jamba_train --out results/hc_jamba.json

Each variant records: hypothesis -> change -> before/after terms -> verdict.
The narrative lands in EXPERIMENTS.md §Perf.
"""
import argparse
import json

from .dryrun import dryrun_one

# variant = (name, hypothesis, overrides/kwargs)
PAIRS = {
    # most memory-bound pair: jamba's XLA-path SSD materializes the
    # (B, n_chunks, L, L, H) within-chunk decay tensor; bytes scale ~ S*L*H
    "jamba_train": {
        "arch": "jamba_1p5_large", "shape": "train_4k",
        "variants": [
            ("ssd_chunk_128",
             "decay tensor bytes scale linearly with chunk L (S*L*H f32 words); "
             "halving L=256->128 should cut SSD intermediate bytes ~2x with "
             "negligible extra cross-chunk state traffic (S/L states of P*N)",
             {"overrides": {"ssm_chunk_size": 128}}),
            ("ssd_chunk_64",
             "same scaling law, L=64: ~4x fewer decay bytes vs baseline; "
             "state-passing overhead (S/L * P * N) still << decay savings",
             {"overrides": {"ssm_chunk_size": 64}}),
            ("moe_gather",
             "jamba is also MoE (16e top-2): replacing one-hot dispatch "
             "einsums by gather/scatter removes the O(S*E*C*D) dispatch "
             "matmuls and the (B,S,E,C) one-hot bytes",
             {"overrides": {"moe_dispatch": "gather"}}),
            ("combined",
             "chunk=64 + gather dispatch + ZeRO-3 + pinned batch axis "
             "compose; memory and collective should both drop",
             {"overrides": {"ssm_chunk_size": 64, "moe_dispatch": "gather",
                            "act_shard_axes": ("data",)}, "zero3": True}),
        ],
    },
    # most collective-bound pair
    "grok_train": {
        "arch": "grok_1_314b", "shape": "train_4k",
        "variants": [
            ("moe_gather",
             "dispatch einsums dominate both FLOPs (S*E*C*D per layer per "
             "direction) and create resharding all-reduces; gather dispatch "
             "eliminates them",
             {"overrides": {"moe_dispatch": "gather"}}),
            ("ce_onehot",
             "vocab=131072 logits are 'model'-sharded; take_along_axis forces "
             "an all-gather of (B,S,V) fp32 logits (~17GB/device-step); "
             "one-hot contraction keeps vocab sharded (psum of (B,S) scalars)",
             {"overrides": {"ce_mode": "onehot"}}),
            ("ff2d_sharding",
             "per-op drilldown: 23.3TB/step of the collective term is "
             "all-reduce, ~364GB/layer -- GSPMD partial-sums the (B,E,C,F) "
             "expert activations because FSDP shards the CONTRACTION dim "
             "(d_model) of w_up/w_gate. 2D-sharding d_ff over (data,model) "
             "instead keeps activations sharded; expected all-reduce drop of "
             "O(F/D)~5x on MoE layers",
             {"ff2d": True}),
            ("zero3_block_gather",
             "ff2d REFUTED: 2D d_ff sharding conflicts with batch-sharded "
             "activations on the same 'data' axis (GSPMD all-gathers tokens "
             "instead). Correct ZeRO-3: all-gather the WEIGHTS per block "
             "just-in-time (with_sharding_constraint inside the scan body) -- "
             "weights are ~3.2GB/layer vs the ~170GB/layer activation "
             "partial-sums GSPMD currently emits",
             {"zero3": True}),
            ("pin_batch",
             "zero3 alone did NOT remove the 170GB/layer all-reduce; HLO "
             "drill shows it appears even without FSDP: GSPMD REPLICATES the "
             "batch axis in the MoE segment (scatter/one-hot backward). Pin "
             "the activation batch dim to the 'data' axis with explicit "
             "sharding constraints inside moe()",
             {"overrides": {"act_shard_axes": ("data",)}}),
            ("gather_zero3_pin",
             "compose: gather dispatch + ZeRO-3 weight gathering + pinned "
             "batch axis",
             {"overrides": {"moe_dispatch": "gather",
                            "act_shard_axes": ("data",)}, "zero3": True}),
        ],
    },
    # paper-representative pair: one DEIS NFE in embedding space
    "gemma_deis": {
        "arch": "gemma_2b", "shape": "deis_4k",
        "variants": [
            ("control_ce_onehot",
             "no CE in this workload -- control variant, expect EXACTLY no change",
             {"overrides": {"ce_mode": "onehot"}}),
            ("seq_shard_state",
             "baseline shards the diffusion state x on d_model ('model' axis), "
             "so every TP matmul resharding moves activations; sequence "
             "sharding (x over 'model' on the SEQ dim) makes the eps update "
             "and history buffer fully local and turns attention into a "
             "kv-all-gather per layer (~67MB vs activation all-reduces)",
             {"deis_shard": "seq"}),
            ("pin_na_control",
             "MoE pin lever is dense-model no-op here -- control",
             {"overrides": {"act_shard_axes": ("data",)}}),
        ],
    },
}


def run_pair(pair_name: str, multi_pod: bool = False):
    spec = PAIRS[pair_name]
    out = {"pair": pair_name, "arch": spec["arch"], "shape": spec["shape"],
           "iterations": []}
    print(f"=== {pair_name}: BASELINE ===")
    base = dryrun_one(spec["arch"], spec["shape"], multi_pod=multi_pod,
                      verbose=False)
    print(json.dumps(base["roofline"]))
    out["baseline"] = base
    prev = base
    for name, hypothesis, kw in spec["variants"]:
        print(f"=== {pair_name}: {name} ===")
        print(f"hypothesis: {hypothesis}")
        res = dryrun_one(spec["arch"], spec["shape"], multi_pod=multi_pod,
                         verbose=False, **kw)
        rb, rv = base["roofline"], res["roofline"]
        deltas = {k: (None if (rb[k] in (None, 0) or rv[k] is None)
                      else round(rv[k] / rb[k], 4)) for k in rb}
        print(f"terms: {json.dumps(rv)}")
        print(f"vs baseline (ratio): {json.dumps(deltas)}")
        out["iterations"].append({
            "name": name, "hypothesis": hypothesis, "result": res,
            "ratio_vs_baseline": deltas,
        })
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=sorted(PAIRS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run_pair(args.pair, args.multi_pod)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
