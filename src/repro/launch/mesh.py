"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state. The dry-run entrypoint (dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing jax.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ('data','model') = 256 chips.
    Multi-pod:  (2, 16, 16) ('pod','data','model') = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over however many devices this host actually has
    (tests / examples on CPU)."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_request_mesh(data: int | None = None):
    """1-axis ('data',) mesh for request-parallel serving/sampling.

    The serving stack shards stacked solves over the REQUEST axis only (the
    eps network is replicated), so its mesh needs just a data axis. ``data``
    defaults to every device this process sees; tests force a multi-device
    host with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set
    BEFORE importing jax).
    """
    n = jax.device_count() if data is None else data
    return jax.make_mesh((n,), ("data",))


def mesh_fingerprint(mesh) -> tuple:
    """Hashable identity of a mesh for compile-cache keys.

    Two meshes with the same axis names/sizes over the same devices (in the
    same order) produce identical executables; anything else must not share
    a cache slot -- in particular, a resharding recompile hides behind a
    mesh swap, which is exactly what cache keys exist to surface.
    """
    return (tuple(mesh.shape.items()),
            tuple(d.id for d in np.ravel(mesh.devices)))


# TPU v5e-ish hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~per-chip usable bisection)
