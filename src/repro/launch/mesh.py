"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state. The dry-run entrypoint (dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing jax.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ('data','model') = 256 chips.
    Multi-pod:  (2, 16, 16) ('pod','data','model') = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Degenerate mesh over however many devices this host actually has
    (tests / examples on CPU)."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e-ish hardware constants for the roofline model (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link (~per-chip usable bisection)
