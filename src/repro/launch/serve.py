"""Serving launcher: AR decode or DEIS diffusion sampling service.

Three diffusion transports:

  sync (default)  -- drain a request list through the engine in-process:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --reduced \
        --mode diffusion --nfe 10 --solver tab3 --requests 8

  driver          -- asyncio demo over the ServeDriver: mixed-priority
                     ragged-NFE requests submitted concurrently via
                     ``submit_async``, per-request progress streamed back:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --reduced \
        --transport driver --requests 6

  http            -- an HTTP-ish endpoint on the driver: POST JSON to
                     /v1/generate ({"seq_len":32,"nfe":10,"solver":"tab3",
                     "seed":0,"priority":0,"deadline_s":null,"stream":true});
                     with "stream" the response is NDJSON StepEvents followed
                     by the final result line:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --reduced \
        --transport http --port 8433

AR mode is unchanged:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --reduced \
        --mode ar --requests 4 --max-new 16
"""
from __future__ import annotations

import argparse
import asyncio
import itertools
import json
import threading

import jax
import numpy as np

from ..configs.base import get_config
from ..models import transformer as T
from ..obs.export import NdjsonExporter, to_prometheus
from ..obs.trace import Tracer
from ..serving.driver import QueueFull, ServeDriver
from ..serving.engine import ARServeEngine, DiffusionServeEngine, Request
from ..training import checkpoint as CKPT


def make_http_server(driver: ServeDriver, port: int = 0):
    """HTTP-ish transport: a threaded stdlib server feeding the driver.

    GET /metrics returns the full serving registry (engine + driver) in the
    Prometheus text exposition format; GET /stats returns the driver's
    summary counters as JSON.

    POST /v1/generate with a JSON body of Request fields (seq_len, nfe,
    solver, eta, seed, priority, deadline_s). Set ``"stream": true`` for an
    NDJSON response: one ``{"event":"step","k":..,"n_steps":..}`` line per
    solver step of the request (its own progress, even inside a ragged
    group), then a ``{"event":"result",...}`` line with tokens and the
    latency/NFE accounting. Without ``stream``, one JSON document with the
    final result. Invalid requests get a 400 carrying the engine's
    validation message. Returns the (not yet serving) HTTPServer; callers
    run ``serve_forever()`` (and may read the bound port off
    ``server.server_address`` when asking for port 0).

    Every handler thread only ever touches the driver's thread-safe
    ``submit`` and the returned handle -- JAX stays on the scheduler thread.
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    uids = itertools.count()

    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.0"   # close-delimited streaming bodies

        def log_message(self, *a):       # keep scheduler logs readable
            pass

        def _json(self, code: int, obj: dict) -> None:
            body = json.dumps(obj).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            # Scrape routes. Handler threads only READ the shared registry
            # (counter/gauge reads are single attribute loads under the GIL;
            # snapshot copies) -- the scheduler thread stays the one writer.
            if self.path == "/metrics":
                body = to_prometheus(driver.engine.metrics).encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
                return
            if self.path == "/stats":
                return self._json(200, driver.stats())
            return self._json(404, {"error": f"no route {self.path}"})

        def do_POST(self):
            if self.path in ("/cancel", "/v1/cancel"):
                # body {"uid": n}: best-effort cancellation of an in-flight
                # request (uids are server-assigned; streaming clients read
                # theirs off the NDJSON step lines). Races with completion
                # resolve in favor of the sample -- "cancelled": false then.
                try:
                    n = int(self.headers.get("Content-Length") or 0)
                    body = json.loads(self.rfile.read(n) or b"{}")
                    uid = int(body["uid"])
                except (KeyError, ValueError, TypeError,
                        json.JSONDecodeError) as e:
                    return self._json(400, {"error": f"bad cancel body: {e}"})
                return self._json(200, {"uid": uid,
                                        "cancelled": driver.cancel(uid)})
            if self.path not in ("/generate", "/v1/generate"):
                return self._json(404, {"error": f"no route {self.path}"})
            try:
                n = int(self.headers.get("Content-Length") or 0)
                body = json.loads(self.rfile.read(n) or b"{}")
                req = Request(
                    uid=next(uids),
                    seq_len=int(body.get("seq_len", 32)),
                    nfe=int(body.get("nfe", 10)),
                    solver=str(body.get("solver", "tab3")),
                    eta=body.get("eta"),
                    seed=int(body.get("seed", 0)),
                    priority=int(body.get("priority", 0)),
                    deadline_s=body.get("deadline_s"))
            except (ValueError, TypeError, json.JSONDecodeError) as e:
                return self._json(400, {"error": f"bad request body: {e}"})
            handle = driver.submit(req)
            if not body.get("stream"):
                try:
                    res = handle.result()
                except QueueFull as e:                 # backpressure shed
                    return self._json(429, {"error": str(e)})
                except (ValueError, TypeError) as e:   # request validation
                    return self._json(400, {"error": str(e)})
                except Exception as e:   # server fault (e.g. failed tick)
                    return self._json(500, {"error": str(e)})
                return self._json(200, _result_json(res))
            # backpressure shed resolves the handle synchronously at submit;
            # catch it BEFORE streaming headers so clients get the documented
            # 429 instead of a 200 with a generic error event
            if handle.done():
                try:
                    handle.result()
                except QueueFull as e:
                    return self._json(429, {"error": str(e)})
                except Exception:
                    pass        # other early failures stream as error events
            # NDJSON streaming: headers first, then a line per step event
            self.send_response(200)
            self.send_header("Content-Type", "application/x-ndjson")
            self.end_headers()
            for ev in handle:
                line = {"event": "step", "uid": req.uid, "k": ev.k,
                        "n_steps": ev.n_steps}
                if ev.tokens is not None:
                    line["tokens"] = np.asarray(ev.tokens).tolist()
                # +inf (no estimate yet) has no strict-JSON literal: the
                # err field appears only once a genuine estimate exists
                if ev.row_err is not None and np.isfinite(ev.row_err[0]):
                    line["err"] = float(ev.row_err[0])
                self.wfile.write((json.dumps(line) + "\n").encode())
                self.wfile.flush()
            try:
                res = handle.result()
            except Exception as e:
                self.wfile.write((json.dumps(
                    {"event": "error", "uid": req.uid, "error": str(e)})
                    + "\n").encode())
                return
            self.wfile.write((json.dumps(
                {"event": "result", **_result_json(res)}) + "\n").encode())

    return ThreadingHTTPServer(("127.0.0.1", port), Handler)


def _result_json(res) -> dict:
    return {"uid": res.uid, "tokens": np.asarray(res.tokens).tolist(),
            "latency_s": res.latency_s, "nfe": res.nfe,
            "compile_s": res.compile_s, "early_exit": res.early_exit,
            "final_err": res.final_err}


async def _driver_demo(driver: ServeDriver, n_requests: int, seq_len: int):
    """Mixed-priority ragged-NFE workload over ``submit_async``."""
    nfes = [4, 8, 12]
    handles = []
    for i in range(n_requests):
        req = Request(uid=i, seq_len=seq_len, nfe=nfes[i % len(nfes)],
                      solver="ddim", seed=i, priority=i % 2,
                      deadline_s=2.0 if i % 2 else None)
        handles.append(await driver.submit_async(req))

    async def consume(h):
        async for ev in h:
            print(f"  req {h.uid}: step {ev.k}/{ev.n_steps}")
        res = await h.result()
        print(f"req {res.uid}: nfe={res.nfe} solve={res.latency_s:.2f}s")
        return res

    return await asyncio.gather(*[consume(h) for h in handles])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=["ar", "diffusion"], default="diffusion")
    ap.add_argument("--transport", choices=["sync", "driver", "http"],
                    default="sync")
    ap.add_argument("--port", type=int, default=8433)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--solver", default="tab3")
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--steps-per-tick", type=int, default=None,
                    help="throttle: groups stepped per tick (enables EDF)")
    ap.add_argument("--no-compaction", action="store_true")
    ap.add_argument("--no-join", action="store_true",
                    help="disable continuous admission (joining pending "
                         "requests into in-flight groups at compaction "
                         "boundaries)")
    ap.add_argument("--seq-len-buckets", default=None,
                    help="comma-separated ascending edges (e.g. 32,64,128): "
                         "request seq_lens round up to a bucket edge so "
                         "nearby lengths share one compiled executor; "
                         "decodes are masked back to each request's length")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="driver backpressure: bound on in-flight requests; "
                         "over it, submits are shed with QueueFull (HTTP 429)")
    ap.add_argument("--early-exit-tol", type=float, default=None,
                    help="retire rows early once their embedded local-error "
                         "estimate drops to TOL (plans compile with "
                         "error_estimate=True; solvers without an embedded "
                         "pair always run their full budget). Results carry "
                         "early_exit/final_err; saved NFEs are counted in "
                         "serve_saved_nfe_total")
    ap.add_argument("--early-exit-min-k", type=int, default=2,
                    help="own-steps floor before the estimate is trusted")
    ap.add_argument("--early-exit-norm", choices=["abs", "rel"], default="abs",
                    help="abs: err <= tol; rel: err <= tol * |x|_inf per row")
    ap.add_argument("--enforce-deadlines", action="store_true",
                    help="evict requests whose absolute deadline passes "
                         "(pending or mid-flight); each evicted request "
                         "fails with DeadlineExceeded on its own handle")
    ap.add_argument("--metrics-ndjson", default=None, metavar="PATH",
                    help="append NDJSON metric snapshots to PATH: every "
                         "--metrics-interval seconds for the http transport, "
                         "one final snapshot for sync/driver")
    ap.add_argument("--metrics-interval", type=float, default=5.0,
                    help="seconds between NDJSON snapshots (http transport)")
    ap.add_argument("--trace-annotate", action="store_true",
                    help="mirror engine spans into jax.profiler "
                         "TraceAnnotations so they attach to device work in "
                         "XLA/perfetto profiles")
    ap.add_argument("--data-parallel", action="store_true",
                    help="shard stacked solves over the request axis on a "
                         "('data',) mesh spanning every visible device "
                         "(force N host devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.with_(objective="diffusion" if args.mode == "diffusion" else "ar")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        params, _ = CKPT.restore(args.ckpt_dir, params)
        print(f"restored params from {args.ckpt_dir}")

    if args.mode == "diffusion":
        mesh = None
        if args.data_parallel:
            from .mesh import make_request_mesh
            mesh = make_request_mesh()
            print(f"request-parallel mesh: {jax.device_count()} devices on "
                  "axis 'data' (group sizes round up to multiples)")
        buckets = tuple(int(e) for e in args.seq_len_buckets.split(",")) \
            if args.seq_len_buckets else None
        retire = None
        if args.early_exit_tol is not None:
            from ..core.adaptive import RetirePolicy
            retire = RetirePolicy(tol=args.early_exit_tol,
                                  min_k=args.early_exit_min_k,
                                  norm=args.early_exit_norm)
            print(f"early exit on: {retire}")
        eng = DiffusionServeEngine(params, cfg,
                                   steps_per_tick=args.steps_per_tick,
                                   compaction=not args.no_compaction,
                                   join=not args.no_join,
                                   seq_len_buckets=buckets,
                                   mesh=mesh,
                                   enforce_deadlines=args.enforce_deadlines,
                                   retire=retire)
        if args.trace_annotate:
            eng.tracer = Tracer(eng.metrics, annotate=True)
        exporter = NdjsonExporter(args.metrics_ndjson,
                                  extra={"arch": args.arch}) \
            if args.metrics_ndjson else None
        if args.transport == "http":
            with ServeDriver(eng, max_pending=args.max_pending) as driver:
                server = make_http_server(driver, args.port)
                host, port = server.server_address
                print(f"serving DEIS on http://{host}:{port}/v1/generate "
                      "(POST JSON; GET /metrics for Prometheus text; "
                      "Ctrl-C to stop)")
                stop_snap = threading.Event()
                if exporter is not None:
                    def _snap_loop():
                        while not stop_snap.wait(args.metrics_interval):
                            exporter.write(eng.metrics)
                    threading.Thread(target=_snap_loop, daemon=True,
                                     name="metrics-ndjson").start()
                try:
                    server.serve_forever()
                except KeyboardInterrupt:
                    pass
                finally:
                    stop_snap.set()
                    server.shutdown()
                    if exporter is not None:
                        exporter.write(eng.metrics)   # final snapshot
                        exporter.close()
            return
        if args.transport == "driver":
            with ServeDriver(eng, max_pending=args.max_pending) as driver:
                results = asyncio.run(
                    _driver_demo(driver, args.requests, args.seq_len))
                print(f"served {len(results)} requests; "
                      f"stats={driver.stats()}")
            if exporter is not None:
                exporter.write(eng.metrics)
                exporter.close()
            return
        reqs = [Request(uid=i, seq_len=args.seq_len, nfe=args.nfe,
                        solver=args.solver, seed=i) for i in range(args.requests)]
        results = eng.serve(
            reqs, on_step=lambda e: print(
                f"  step {e.k}/{e.n_steps} for uids {e.uids}"))
        for r in results[:4]:
            print(f"req {r.uid}: nfe={r.nfe} solve={r.latency_s:.2f}s "
                  f"compile={r.compile_s:.2f}s early_exit={r.early_exit} "
                  f"tokens[:10]={r.tokens[:10]}")
        print(f"served {len(results)} requests")
        if retire is not None:
            m = eng.metrics
            print(f"early exits: "
                  f"{int(m.get('serve_early_exit_total').value)}/"
                  f"{len(results)}, saved NFEs: "
                  f"{int(m.get('serve_saved_nfe_total').value)}")
        if exporter is not None:
            exporter.write(eng.metrics)
            exporter.close()
    else:
        eng = ARServeEngine(params, cfg, max_len=args.seq_len + args.max_new)
        rng = np.random.RandomState(0)
        reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 8),
                        max_new_tokens=args.max_new) for i in range(args.requests)]
        results = eng.serve(reqs)
        for r in results[:4]:
            print(f"req {r.uid}: latency={r.latency_s:.2f}s tokens={r.tokens[:10]}")
        print(f"served {len(results)} requests")


if __name__ == "__main__":
    main()
