"""Serving launcher: AR decode or DEIS diffusion sampling service.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --reduced \
        --mode diffusion --nfe 10 --solver tab3 --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --reduced \
        --mode ar --requests 4 --max-new 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs.base import get_config
from ..models import transformer as T
from ..serving.engine import ARServeEngine, DiffusionServeEngine, Request
from ..training import checkpoint as CKPT


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=["ar", "diffusion"], default="diffusion")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--solver", default="tab3")
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.with_(objective="diffusion" if args.mode == "diffusion" else "ar")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        params, _ = CKPT.restore(args.ckpt_dir, params)
        print(f"restored params from {args.ckpt_dir}")

    if args.mode == "diffusion":
        eng = DiffusionServeEngine(params, cfg)
        reqs = [Request(uid=i, seq_len=args.seq_len, nfe=args.nfe,
                        solver=args.solver, seed=i) for i in range(args.requests)]
        results = eng.serve(
            reqs, on_step=lambda e: print(
                f"  step {e.k}/{e.n_steps} for uids {e.uids}"))
        for r in results[:4]:
            print(f"req {r.uid}: nfe={r.nfe} solve={r.latency_s:.2f}s "
                  f"compile={r.compile_s:.2f}s tokens[:10]={r.tokens[:10]}")
        print(f"served {len(results)} requests")
    else:
        eng = ARServeEngine(params, cfg, max_len=args.seq_len + args.max_new)
        rng = np.random.RandomState(0)
        reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 8),
                        max_new_tokens=args.max_new) for i in range(args.requests)]
        results = eng.serve(reqs)
        for r in results[:4]:
            print(f"req {r.uid}: latency={r.latency_s:.2f}s tokens={r.tokens[:10]}")
        print(f"served {len(results)} requests")


if __name__ == "__main__":
    main()
