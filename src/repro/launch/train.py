"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma_2b --reduced \
        --steps 50 --batch 8 --seq 64 [--objective diffusion|ar] \
        [--ckpt-dir ckpts/run1] [--model-parallel 1]

On this CPU host the mesh is (n_devices/model, model); on a real cluster the
same script runs under the production mesh (launch/mesh.py).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs.base import get_config
from ..data.pipeline import MarkovTextSource, make_batch
from ..models import transformer as T
from ..sharding import rules as R
from ..training import checkpoint as CKPT
from ..training.optimizer import AdamW, cosine_schedule
from ..training.steps import make_train_step
from .mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--objective", default="diffusion")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.with_(objective=args.objective)
    mesh = make_host_mesh(model=args.model_parallel)
    print(f"arch={cfg.name} objective={cfg.objective} mesh={dict(mesh.shape)}")

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = AdamW(cosine_schedule(args.lr, max(1, args.steps // 10), args.steps))
    opt_state = opt.init(params)

    shape_of = lambda t: jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), t)
    pspec = R.param_specs(shape_of(params), mesh)
    psh = R.to_shardings(pspec, mesh)
    osh = R.to_shardings(R.opt_state_specs(shape_of(opt_state), pspec, mesh), mesh)
    step = jax.jit(make_train_step(cfg, opt), in_shardings=(psh, osh, None, None),
                   donate_argnums=(0, 1))

    start = 0
    if args.ckpt_dir and CKPT.latest_step(args.ckpt_dir) is not None:
        (params, opt_state), meta = CKPT.restore(args.ckpt_dir,
                                                 (params, opt_state))
        start = meta.get("next_step", 0)
        print(f"restored checkpoint at step {start}")

    src = MarkovTextSource(cfg.vocab_size, args.seed)
    rng = jax.random.PRNGKey(args.seed + 1)
    t0 = time.perf_counter()
    with mesh:
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     make_batch(cfg, src, i, args.batch, args.seq).items()}
            rng, sub = jax.random.split(rng)
            params, opt_state, m = step(params, opt_state, batch, sub)
            if i % max(1, args.steps // 10) == 0:
                print(f"step {i:5d} loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f} "
                      f"({(time.perf_counter() - t0):.1f}s)")
            if args.ckpt_dir and args.ckpt_every and \
                    (i + 1) % args.ckpt_every == 0:
                CKPT.save(args.ckpt_dir, i + 1, (params, opt_state),
                          {"next_step": i + 1, "arch": cfg.name})
    if args.ckpt_dir:
        CKPT.save(args.ckpt_dir, args.steps, (params, opt_state),
                  {"next_step": args.steps, "arch": cfg.name})
        print(f"final checkpoint -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()
