"""Backbone building blocks: norms, RoPE, attention (GQA/MQA/SWA, KV cache),
GLU MLPs, MoE (GShard-style capacity dispatch), time conditioning.

Pure functions over parameter pytrees (no flax). All matmuls via einsum with
``preferred_element_type=float32`` accumulation when inputs are bf16.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig


def _acc(x):
    """Accumulation dtype for mixed-precision einsums."""
    return jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float16) else x.dtype


def matmul(x, w):
    out = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=_acc(x))
    return out.astype(x.dtype)


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    normed = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def sinusoidal_embedding(t, dim: int, max_period: float = 10_000.0):
    """Timestep embedding for diffusion conditioning (t scalar or (B,))."""
    t = jnp.atleast_1d(t)
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t[:, None].astype(jnp.float32) * freqs[None, :] * 1000.0
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


# --------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, positions, theta: float):
    """positions: (...,S) int -> cos/sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D). Rotates pairs (x1, x2) = split halves."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def attention_scores(q, k, v, mask, softcap: float = 0.0):
    """q: (B,Sq,H,D), k/v: (B,Sk,H,D) (already GQA-expanded). mask broadcastable
    to (B, H, Sq, Sk) boolean (True = attend). fp32 softmax."""
    d = q.shape[-1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) / math.sqrt(d)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def make_attention_mask(q_pos, kv_pos, causal: bool, window: int = 0,
                        kv_valid=None):
    """Boolean mask (B?, 1, Sq, Sk) from position tensors (broadcast (S,) ok)."""
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), dtype=bool)
    if causal:
        mask = mask & (kp <= qp)
    if window:
        mask = mask & (kp > qp - window)
    if kv_valid is not None:
        mask = mask & kv_valid[..., None, :]
    return mask[..., None, :, :] if mask.ndim == 2 else mask[:, None]


def init_attention(key, cfg: ModelConfig, dtype):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "wq": (jax.random.normal(ks[0], (d, qd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kvd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kvd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (qd, d)) * s / math.sqrt(2 * cfg.n_layers)).astype(dtype),
    }


def attention(params, cfg: ModelConfig, x, positions, *, causal=True,
              cache=None, cache_index=None, kv_override=None,
              return_kv: bool = False, use_pallas: bool = False,
              valid_len=None):
    """Multi-head attention with GQA + RoPE + optional SWA and KV cache.

    cache: None (train/prefill w/o cache) or dict {k, v} with shape
      (B, S_cache, KV, D); decode writes current kv at ``cache_index``.
    kv_override: (k, v) for cross-attention (already projected).
    return_kv: prefill mode -- return the (post-RoPE) KV as a cache (ring
    layout of window size for SWA archs).
    valid_len: optional (B,) int -- per-row true sequence length when rows
      are right-padded to a bucketed S; key positions >= valid_len are
      masked out so row content is independent of the bucket it landed in.
    Returns (out, new_cache).
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = matmul(x, params["wq"]).reshape(b, s, cfg.n_heads, hd)
    if kv_override is None:
        k = matmul(x, params["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
        v = matmul(x, params["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
        cos, sin = rope_frequencies(hd, positions, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    else:
        k, v = kv_override

    new_cache = None
    if return_kv and cache is None and kv_override is None:
        if cfg.sliding_window and s > cfg.sliding_window:
            w = cfg.sliding_window
            pos0 = s - w
            idxs = np.arange(pos0, s) % w
            ck = jnp.zeros((b, w) + k.shape[2:], k.dtype).at[:, idxs].set(k[:, pos0:])
            cv = jnp.zeros((b, w) + v.shape[2:], v.dtype).at[:, idxs].set(v[:, pos0:])
            new_cache = {"k": ck, "v": cv}
        else:
            new_cache = {"k": k, "v": v}
    if cache is not None and kv_override is None:
        # decode: write this step's kv into the cache at cache_index (ring
        # buffer for SWA), then attend over the whole cache
        s_cache = cache["k"].shape[1]
        if cfg.sliding_window and s_cache == cfg.sliding_window:
            write_idx = jnp.mod(cache_index, s_cache)
        else:
            write_idx = cache_index
        write_idx = write_idx.astype(jnp.int32) if hasattr(write_idx, "astype") \
            else jnp.int32(write_idx)
        zero = jnp.zeros((), jnp.int32)
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (zero, write_idx, zero, zero))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (zero, write_idx, zero, zero))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv

    if use_pallas and cache is None and kv_override is None \
            and valid_len is None:
        # full-sequence self-attention through the Pallas flash kernel
        # (interpret mode off-TPU); GQA handled inside the kernel's index
        # maps -- kv heads are never materialized n_rep times
        from ..kernels.ops import flash_attention as _flash
        out = _flash(q, k, v, causal=causal, window=cfg.sliding_window)
        out = matmul(out.reshape(b, s, cfg.q_dim), params["wo"])
        return out, new_cache

    n_rep = cfg.n_heads // max(1, k.shape[2])
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)

    if cache is not None and kv_override is None:
        s_cache = k.shape[1]
        if cfg.sliding_window and s_cache == cfg.sliding_window:
            # ring buffer: valid positions are cache_index - window + 1 .. cache_index
            slot = jnp.arange(s_cache)
            age = jnp.mod(cache_index - slot, s_cache)
            kv_pos = cache_index - age
            valid = kv_pos >= 0
            mask = (kv_pos <= positions[..., :, None]) & valid
            mask = mask[:, None] if mask.ndim == 3 else mask[None, None]
        else:
            kv_pos = jnp.arange(s_cache)
            mask = kv_pos[None, None, None, :] <= positions[..., :, None][:, None]
            if cfg.sliding_window:
                mask = mask & (kv_pos[None, None, None, :] >
                               positions[..., :, None][:, None] - cfg.sliding_window)
    elif kv_override is not None:
        mask = jnp.ones((1, 1, s, k.shape[1]), dtype=bool)
    else:
        kv_pos = positions
        kv_valid = None
        if valid_len is not None:
            kv_valid = jnp.arange(s)[None, :] < valid_len[:, None]
        mask = make_attention_mask(positions, kv_pos, causal,
                                   cfg.sliding_window, kv_valid=kv_valid)

    out = attention_scores(q, k, v, mask, cfg.logit_softcap)
    out = matmul(out.reshape(b, s, cfg.q_dim), params["wo"])
    return out, new_cache


# --------------------------------------------------------------------- MLPs
def init_mlp(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    p = {"w_up": (jax.random.normal(ks[0], (d, f)) * s).astype(dtype),
         "w_down": (jax.random.normal(ks[1], (f, d)) * s / math.sqrt(2 * cfg.n_layers)).astype(dtype)}
    if cfg.glu:
        p["w_gate"] = (jax.random.normal(ks[2], (d, f)) * s).astype(dtype)
    return p


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp(params, cfg: ModelConfig, x):
    up = matmul(x, params["w_up"])
    if cfg.glu:
        up = _act(cfg.act)(matmul(x, params["w_gate"])) * up
    else:
        up = _act(cfg.act)(up)
    return matmul(up, params["w_down"])


# ---------------------------------------------------------------------- MoE
def init_moe(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "router": (jax.random.normal(ks[0], (d, e)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * s).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * s).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * s / math.sqrt(2 * cfg.n_layers)).astype(dtype),
    }


def moe(params, cfg: ModelConfig, x, *, expert_parallel: bool = False):
    """Top-k capacity-based MoE. Two dispatch modes (cfg.moe_dispatch):

    'einsum' -- GShard one-hot dispatch matmuls (classic TPU idiom; baseline).
                Costs an extra O(S*E*C*D) matmul + an O(S*E*C) one-hot tensor
                each way.
    'gather' -- scatter/gather dispatch: build an (E, C) token-index table,
                gather expert inputs, combine by weighted scatter-equivalent
                one-hot on the RETURN path only where cheap. Removes the
                dispatch matmul FLOPs/bytes entirely (EXPERIMENTS.md §Perf,
                grok iteration).
    Returns (out, aux_losses)."""
    mcfg = cfg.moe
    b, s, d = x.shape
    e, k = mcfg.num_experts, mcfg.top_k
    n_tok = s
    cap = max(1, int(mcfg.capacity_factor * n_tok * k / e))
    cap = min(cap, n_tok)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    gates = jax.nn.softmax(logits, axis=-1)                      # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(gates, k)                # (B,S,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # position of each (token, choice) within its expert queue
    choice_onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (B,S,k,E)
    flat = choice_onehot.reshape(b, s * k, e)
    pos_f = jnp.cumsum(flat, axis=1) - flat                      # (B,S*k,E)
    pos_f = pos_f.reshape(b, s, k, e)
    pos = jnp.sum(pos_f * choice_onehot, axis=-1)                # (B,S,k) slot idx
    within_cap = pos < cap

    def _pin_batch(t):
        """Pin the leading (batch) dim to the configured data axes so GSPMD's
        scatter-add backward cannot silently replicate the batch (observed:
        ~170GB/layer all-reduces of batch-replicated expert grads)."""
        if cfg.act_shard_axes is None:
            return t
        from jax.sharding import PartitionSpec as _P
        spec = _P(tuple(cfg.act_shard_axes), *([None] * (t.ndim - 1)))
        return jax.lax.with_sharding_constraint(t, spec)

    if cfg.moe_dispatch == "gather":
        # token index table per (expert, slot): scatter token ids
        tok_ids = jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, k))
        flat_slot = (gate_idx * cap + pos.astype(jnp.int32)).reshape(b, s * k)
        valid = within_cap.reshape(b, s * k)
        upd = jnp.where(valid, tok_ids.reshape(b, s * k), 0).astype(jnp.int32)
        # out-of-capacity entries scatter to a dustbin slot (e*cap)
        slot = jnp.where(valid, flat_slot, e * cap).astype(jnp.int32)
        table = jnp.zeros((b, e * cap + 1), jnp.int32).at[
            jnp.arange(b)[:, None], slot].set(upd)[:, :-1]
        occupied = jnp.zeros((b, e * cap + 1), jnp.bool_).at[
            jnp.arange(b)[:, None], slot].set(valid)[:, :-1]
        xin = jnp.take_along_axis(x, table[..., None], axis=1)   # (B,E*C,D)
        xin = jnp.where(occupied[..., None], xin, 0).reshape(b, e, cap, d)
        xin = _pin_batch(xin)
        h = jnp.einsum("becd,edf->becf", xin, params["w_up"])
        g = jnp.einsum("becd,edf->becf", xin, params["w_gate"])
        h = (jax.nn.silu(g.astype(jnp.float32)) * h.astype(jnp.float32)).astype(x.dtype)
        out_e = jnp.einsum("becf,efd->becd", _pin_batch(h), params["w_down"])
        out_e = _pin_batch(out_e.reshape(b, e * cap, d))
        # return path: each token gathers its k slots back (dropped tokens
        # read slot 0 but are zero-weighted below)
        gflat = (gate_idx * cap + pos.astype(jnp.int32)).reshape(b, s * k)
        gflat = jnp.where(valid, gflat, 0)
        got = jnp.take_along_axis(out_e, gflat[..., None], axis=1)  # (B,S*k,D)
        got = _pin_batch(got.reshape(b, s, k, d))
        w = (gate_vals * within_cap).astype(got.dtype)
        out = _pin_batch(jnp.einsum("bsk,bskd->bsd", w, got))
        frac_dispatched = jnp.mean(
            jnp.sum(choice_onehot * within_cap[..., None], axis=2), axis=(0, 1))
    else:
        pos_onehot = jax.nn.one_hot(pos, cap, dtype=jnp.float32)     # (B,S,k,C)
        disp_k = choice_onehot[..., None] * pos_onehot[..., None, :] \
            * within_cap[..., None, None]                             # (B,S,k,E,C)
        dispatch = jnp.sum(disp_k, axis=2)                            # (B,S,E,C)
        combine = jnp.einsum("bsk,bskec->bsec", gate_vals, disp_k)
        xin = _pin_batch(jnp.einsum("bsec,bsd->becd", dispatch.astype(x.dtype), x))
        h = jnp.einsum("becd,edf->becf", xin, params["w_up"])
        g = jnp.einsum("becd,edf->becf", xin, params["w_gate"])
        h = (jax.nn.silu(g.astype(jnp.float32)) * h.astype(jnp.float32)).astype(x.dtype)
        out_e = jnp.einsum("becf,efd->becd", _pin_batch(h), params["w_down"])
        out = _pin_batch(jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), out_e))
        frac_dispatched = jnp.mean(jnp.sum(dispatch, axis=-1), axis=(0, 1))

    # aux losses (Switch/GShard): load-balance + router z-loss
    me = jnp.mean(gates, axis=(0, 1))                             # mean gate prob
    lb_loss = e * jnp.sum(me * frac_dispatched) * mcfg.load_balance_loss
    z_loss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2) * mcfg.router_z_loss
    return out, {"moe_lb": lb_loss, "moe_z": z_loss}
