"""Mamba2 / SSD layer (arXiv:2405.21060), TPU-adapted.

State-space duality form with scalar-per-head decay:

    h_t = a_t h_{t-1} + b_t x_t^T      (per head: h in R^{P x N})
    y_t = C_t h_t

Training/prefill uses the CHUNKED algorithm (the paper's SSD): within-chunk
quadratic attention-like term (MXU matmuls) + across-chunk state recurrence
(lax.scan over chunks). Decode is the O(1) recurrent update. This is the
TPU-native rethink of the CUDA selective-scan: all heavy ops are dense
matmuls over (chunk x chunk) and (P x N) tiles, MXU-friendly; the sequential
part is only n_chunks long. A Pallas kernel version lives in repro/kernels.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def ssm_dims(cfg: ModelConfig):
    d_inner = cfg.ssm.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm.head_dim
    return d_inner, n_heads


def init_ssm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    scfg = cfg.ssm
    d_inner, n_heads = ssm_dims(cfg)
    n = scfg.state_dim
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    # in_proj produces [z (gate), x, B, C, dt] along features
    proj_out = 2 * d_inner + 2 * n + n_heads
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (scfg.conv_width, d_inner + 2 * n)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner + 2 * n,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),        # A = -exp(A_log)
        "dt_bias": jnp.full((n_heads,), math.log(math.e - 1), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), dtype),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d)) * s / math.sqrt(2 * cfg.n_layers)).astype(dtype),
    }


def _split_proj(cfg, zxbcdt):
    d_inner, n_heads = ssm_dims(cfg)
    n = cfg.ssm.state_dim
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv, width K. xbc: (B,S,C). state: (B,K-1,C) or None.
    Returns (out, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros(xbc.shape[:1] + (k - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xbc], axis=1)               # (B, S+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype), new_state


def ssd_chunked(x, a, B, C, chunk: int):
    """SSD chunked scan.

    x: (B, S, H, P) inputs; a: (B, S, H) per-step decay in (0,1);
    B, C: (B, S, N) shared across heads (multi-value attention analogy).
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    bsz, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk:
        # pad seq to a chunk multiple with identity (a=1, x=0) steps at the end
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        y, final = ssd_chunked(x, a, B, C, chunk)
        return y[:, :s], final
    nc = s // chunk
    xr = x.reshape(bsz, nc, chunk, h, p)
    ar = a.reshape(bsz, nc, chunk, h)
    Br = B.reshape(bsz, nc, chunk, n)
    Cr = C.reshape(bsz, nc, chunk, n)

    log_a = jnp.log(ar.astype(jnp.float32))                # (B,nc,L,H)
    cum = jnp.cumsum(log_a, axis=2)                        # inclusive cumsum
    # within-chunk: y_intra[t] = sum_{u<=t} C_t . B_u * exp(cum_t - cum_u) x_u
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,T,U,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bctn,bcun->bctu", Cr.astype(jnp.float32), Br.astype(jnp.float32))
    w = scores[..., None] * decay                          # (B,nc,T,U,H)
    y_intra = jnp.einsum("bctuh,bcuhp->bcthp", w, xr.astype(jnp.float32))

    # chunk summaries: S_c = sum_u exp(cum_last - cum_u) B_u x_u^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (B,nc,L,H)
    chunk_state = jnp.einsum("bcuh,bcun,bcuhp->bchpn",
                             decay_to_end, Br.astype(jnp.float32), xr.astype(jnp.float32))
    a_chunk = jnp.exp(cum[:, :, -1, :])                    # (B,nc,H) total decay

    # recurrence across chunks
    def step(carry, inp):
        s_prev = carry                                      # (B,H,P,N)
        s_c, a_c = inp
        s_new = s_prev * a_c[..., None, None] + s_c
        return s_new, s_prev

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(a_chunk, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B,nc,H,P,N)

    # inter-chunk: y_inter[t] = C_t . (decay_from_start_t * S_{c-1})
    decay_from_start = jnp.exp(cum)                         # (B,nc,L,H)
    y_inter = jnp.einsum("bctn,bcth,bchpn->bcthp",
                         Cr.astype(jnp.float32), decay_from_start, prev_states)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, final


def ssm_forward(params, cfg: ModelConfig, x, *, cache=None, use_pallas: bool = False):
    """Full Mamba2 mixer. x: (B, S, D).

    cache: None (train/prefill) or dict {conv: (B,K-1,C), state: (B,H,P,N)}
    for O(1) decode (S must be 1). Returns (out, new_cache).
    """
    bsz, s, _ = x.shape
    scfg = cfg.ssm
    d_inner, n_heads = ssm_dims(cfg)
    n, p = scfg.state_dim, scfg.head_dim

    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"]).astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B,S,H)
    A = -jnp.exp(params["A_log"])                                       # (H,)
    a = jnp.exp(dt * A)                                                 # decay in (0,1)

    if cache is None:
        xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        xs, B, C = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
        xh = xs.reshape(bsz, s, n_heads, p)
        if use_pallas:
            from ..kernels.ops import ssd_scan as _ssd
            y, state = _ssd(xh, a, B, C, chunk=min(scfg.chunk_size, s))
        else:
            y, state = ssd_chunked(xh, a, B, C, chunk=min(scfg.chunk_size, s))
        new_cache = {"conv": conv_state, "state": state}
    else:
        assert s == 1
        xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                       state=cache["conv"])
        xs, B, C = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
        xh = xs.reshape(bsz, 1, n_heads, p).astype(jnp.float32)
        a1 = a[:, 0]                                                    # (B,H)
        st = cache["state"]                                             # (B,H,P,N)
        st = st * a1[..., None, None] + jnp.einsum(
            "bn,bhp->bhpn", B[:, 0].astype(jnp.float32), xh[:, 0])
        y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), st)[:, None]
        state = st
        new_cache = {"conv": conv_state, "state": state}

    y = y + params["D"][None, None, :, None] * (xs.reshape(bsz, s, n_heads, p).astype(jnp.float32))
    y = y.reshape(bsz, s, d_inner)
    # gated RMSNorm (mamba2)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * (1.0 + params["norm_scale"].astype(jnp.float32))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["out_proj"])
    return out, new_cache
