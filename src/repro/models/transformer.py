"""Backbone assembly: init + forward for all arch families.

Layers are grouped into repeating BLOCKS and parameters are stacked with a
leading ``n_blocks`` dim; the forward pass is a single ``lax.scan`` over
blocks. This keeps HLO size O(block) instead of O(n_layers) -- essential for
compiling 64-72 layer configs for 512 devices -- and gives natural remat
boundaries.

Block layouts:
  dense / moe / ssm : block = 1 layer
  hybrid (jamba)    : block = ``attn_every`` layers, attention at the middle
                      slot, MoE MLP on odd slots (1:7 mamba:attn, 16e top-2)
  encdec (whisper)  : encoder stack (bidirectional) + decoder stack with
                      cross-attention; frontend embeddings come in via
                      ``frames`` (stub carve-out)
  vlm (paligemma)   : image-patch ``prefix`` embeddings prepended to text

Modes: 'train' (full seq), 'prefill' (full seq -> returns KV cache),
'decode' (one token against cache at ``cache_index``).
Objectives: 'ar' (causal LM) and 'diffusion' (bidirectional denoiser with
time conditioning -- the paper's eps_theta; see repro/diffusion/lm.py).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import ssm as S


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def block_size(cfg: ModelConfig) -> int:
    if cfg.arch_type == "hybrid":
        return cfg.attn_every
    return 1


def n_blocks(cfg: ModelConfig) -> int:
    bs = block_size(cfg)
    assert cfg.n_layers % bs == 0, (cfg.n_layers, bs)
    return cfg.n_layers // bs


def _layer_kind(cfg: ModelConfig, slot: int) -> tuple[str, str]:
    """(mixer, mlp) kinds for slot within a block."""
    if cfg.arch_type == "ssm":
        mixer = "ssm"
    elif cfg.arch_type == "hybrid":
        mixer = "attn" if slot == (cfg.attn_every // 2) else "ssm"
    else:
        mixer = "attn"
    if cfg.moe is None:
        mlp = "dense"
    elif cfg.moe_every and cfg.moe_every > 1:
        mlp = "moe" if (slot % cfg.moe_every) == 1 else "dense"
    else:
        mlp = "moe"
    if cfg.arch_type == "ssm":
        mlp = "none"  # mamba2 blocks have no separate MLP
    return mixer, mlp


# ------------------------------------------------------------------- init
def _init_block(key, cfg: ModelConfig, dtype, cross_attn: bool = False):
    p: dict[str, Any] = {}
    for slot in range(block_size(cfg)):
        mixer, mlpk = _layer_kind(cfg, slot)
        keys = jax.random.split(jax.random.fold_in(key, slot), 4)
        sp: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
        if mixer == "attn":
            sp["attn"] = L.init_attention(keys[0], cfg, dtype)
        else:
            sp["ssm"] = S.init_ssm(keys[0], cfg, dtype)
        if cross_attn:
            sp["norm_x"] = jnp.zeros((cfg.d_model,), dtype)
            sp["cross"] = L.init_attention(keys[3], cfg, dtype)
        if mlpk != "none":
            sp["norm2"] = jnp.zeros((cfg.d_model,), dtype)
            sp["mlp" if mlpk == "dense" else "moe"] = (
                L.init_mlp(keys[1], cfg, dtype) if mlpk == "dense"
                else L.init_moe(keys[1], cfg, dtype))
        p[f"slot{slot}"] = sp
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = _dtype(cfg)
    nb = n_blocks(cfg)
    keys = jax.random.split(key, nb + 8)
    blocks = [_init_block(keys[i], cfg, dtype, cross_attn=(cfg.arch_type == "encdec"))
              for i in range(nb)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)

    p: dict[str, Any] = {
        "embed": (jax.random.normal(keys[nb], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "blocks": stacked,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(keys[nb + 1], (cfg.d_model, cfg.vocab_size))
                        * 0.02).astype(dtype)
    if cfg.objective == "diffusion":
        te = cfg.time_emb_dim
        p["time_mlp"] = {
            "w1": (jax.random.normal(keys[nb + 2], (te, cfg.d_model)) * 0.02).astype(dtype),
            "b1": jnp.zeros((cfg.d_model,), dtype),
            "w2": (jax.random.normal(keys[nb + 3], (cfg.d_model, cfg.d_model)) * 0.02).astype(dtype),
            "b2": jnp.zeros((cfg.d_model,), dtype),
        }
        p["eps_head"] = (jax.random.normal(keys[nb + 4], (cfg.d_model, cfg.d_model)) * 0.02).astype(dtype)
    if cfg.arch_type == "encdec":
        enc_blocks = [_init_block(jax.random.fold_in(keys[nb + 5], i), cfg, dtype)
                      for i in range(cfg.encoder_layers)]
        p["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks)
        p["enc_final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    return p


# ------------------------------------------------------------------ cache
def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None,
               enc_out=None, params=None) -> dict:
    """Pre-allocated decode cache. For SWA archs the attention cache is a ring
    buffer of window size. SSM slots carry (conv, state)."""
    dtype = dtype or _dtype(cfg)
    hd = cfg.resolved_head_dim
    eff_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    d_inner, n_heads_ssm = (S.ssm_dims(cfg) if cfg.ssm else (0, 0))

    def one_block():
        c = {}
        for slot in range(block_size(cfg)):
            mixer, _ = _layer_kind(cfg, slot)
            if mixer == "attn":
                c[f"slot{slot}"] = {
                    "k": jnp.zeros((batch, eff_len, cfg.n_kv_heads, hd), dtype),
                    "v": jnp.zeros((batch, eff_len, cfg.n_kv_heads, hd), dtype),
                }
            else:
                n = cfg.ssm.state_dim
                c[f"slot{slot}"] = {
                    "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, d_inner + 2 * n), dtype),
                    "state": jnp.zeros((batch, n_heads_ssm, cfg.ssm.head_dim, n), jnp.float32),
                }
        return c

    cache = {"blocks": jax.tree.map(lambda *xs: jnp.stack(xs),
                                    *[one_block() for _ in range(n_blocks(cfg))])}
    if cfg.arch_type == "encdec":
        # precomputed cross-attention KV per decoder block
        if enc_out is not None and params is not None:
            def cross_kv(block_p):
                sp = block_p["slot0"]["cross"]
                k = L.matmul(enc_out, sp["wk"]).reshape(batch, -1, cfg.n_kv_heads, hd)
                v = L.matmul(enc_out, sp["wv"]).reshape(batch, -1, cfg.n_kv_heads, hd)
                return {"k": k, "v": v}
            cache["cross"] = jax.vmap(cross_kv)(params["blocks"]) if False else \
                jax.lax.map(cross_kv, params["blocks"])
        else:
            cache["cross"] = {
                "k": jnp.zeros((n_blocks(cfg), batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((n_blocks(cfg), batch, cfg.encoder_seq, cfg.n_kv_heads, hd), dtype),
            }
    return cache


# ---------------------------------------------------------------- forward
def _apply_block(cfg: ModelConfig, bp, h, positions, *, causal, cache_b,
                 cache_index, enc_out, collect_kv=False, use_pallas=False,
                 valid_len=None):
    aux = {}
    new_cache_b = {} if (cache_b is not None or collect_kv) else None
    for slot in range(block_size(cfg)):
        sp = bp[f"slot{slot}"]
        mixer, mlpk = _layer_kind(cfg, slot)
        c_slot = cache_b[f"slot{slot}"] if cache_b is not None else None
        hn = L.rms_norm(h, sp["norm1"], cfg.norm_eps)
        if mixer == "attn":
            out, nc = L.attention(sp["attn"], cfg, hn, positions, causal=causal,
                                  cache=c_slot, cache_index=cache_index,
                                  return_kv=collect_kv, use_pallas=use_pallas,
                                  valid_len=valid_len)
        else:
            out, nc = S.ssm_forward(sp["ssm"], cfg, hn, cache=c_slot,
                                    use_pallas=use_pallas)
        h = h + out
        if new_cache_b is not None:
            # repro: allow[RL002] KV-cache pytree keyed by trace-static layer slot, not a compile cache
            new_cache_b[f"slot{slot}"] = nc if nc is not None else c_slot
        if "cross" in sp and enc_out is not None:
            hx = L.rms_norm(h, sp["norm_x"], cfg.norm_eps)
            b = hx.shape[0]
            hd = cfg.resolved_head_dim
            if isinstance(enc_out, dict):   # precomputed cross KV (decode)
                kv = (enc_out["k"], enc_out["v"])
            else:
                k = L.matmul(enc_out, sp["cross"]["wk"]).reshape(b, -1, cfg.n_kv_heads, hd)
                v = L.matmul(enc_out, sp["cross"]["wv"]).reshape(b, -1, cfg.n_kv_heads, hd)
                kv = (k, v)
            out, _ = L.attention(sp["cross"], cfg, hx, positions, causal=False,
                                 kv_override=kv)
            h = h + out
        if mlpk != "none":
            hn = L.rms_norm(h, sp["norm2"], cfg.norm_eps)
            if mlpk == "dense":
                h = h + L.mlp(sp["mlp"], cfg, hn)
            else:
                out, moe_aux = L.moe(sp["moe"], cfg, hn)
                h = h + out
                for k2, v2 in moe_aux.items():
                    aux[k2] = aux.get(k2, 0.0) + v2
    return h, new_cache_b, aux


def _run_encoder(params, cfg: ModelConfig, frames, unroll: int = 1):
    h = frames.astype(_dtype(cfg))
    positions = jnp.broadcast_to(jnp.arange(h.shape[1])[None], h.shape[:2])

    def body(carry, bp):
        h = carry
        h, _, _ = _apply_block(cfg, bp, h, positions, causal=False, cache_b=None,
                               cache_index=None, enc_out=None)
        return h, None

    enc_unroll = cfg.encoder_layers if (unroll is True or unroll == 0
                                        or unroll > 1) else 1
    h, _ = jax.lax.scan(body, h, params["encoder"], unroll=enc_unroll)
    return L.rms_norm(h, params["enc_final_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None, prefix=None,
            frames=None, mode: str = "train", cache=None, cache_index=None,
            t_cond=None, causal: Optional[bool] = None, use_pallas: bool = False,
            remat: bool = False, unroll: int = 1, block_constraint=None,
            valid_len=None):
    """block_constraint: optional pytree (matching one stacked block's param
    subtree) of NamedShardings applied to the block params INSIDE the scan
    body -- ZeRO-3 semantics: FSDP-sharded weights are all-gathered per block
    just-in-time and freed after (EXPERIMENTS.md §Perf, grok iteration).

    valid_len: optional (B,) int per-row true length for bucket-padded
    batches; threaded to attention so padded tail keys are masked out."""
    """Returns dict(logits | eps, cache, aux).

    tokens: (B,S) int32; embeds: (B,S,D) continuous input (diffusion mode);
    prefix: (B,P,D) VLM patch embeddings; frames: (B,F,D) audio embeddings.
    """
    dtype = _dtype(cfg)
    if causal is None:
        causal = cfg.objective != "diffusion"

    if embeds is not None:
        h = embeds.astype(dtype)
    else:
        h = params["embed"][tokens].astype(dtype)
        if cfg.arch_type == "vlm" and mode != "decode" and prefix is not None:
            h = jnp.concatenate([prefix.astype(dtype), h], axis=1)

    b, s, _ = h.shape
    if mode == "decode":
        positions = jnp.broadcast_to(cache_index, (b, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    if t_cond is not None:
        te = L.sinusoidal_embedding(t_cond, cfg.time_emb_dim).astype(dtype)
        tm = params["time_mlp"]
        te = jax.nn.silu((te @ tm["w1"] + tm["b1"]).astype(jnp.float32)).astype(dtype)
        te = (te @ tm["w2"] + tm["b2"])
        h = h + te[:, None, :] if te.shape[0] == b else h + te[None, None, :]

    enc_out = None
    if cfg.arch_type == "encdec":
        if mode == "decode":
            enc_out = "cached"  # replaced per-block from cache['cross']
        else:
            assert frames is not None
            enc_out = _run_encoder(params, cfg, frames, unroll=unroll)

    collect_kv = (mode == "prefill")

    def body_inner(carry, xs):
        h = carry
        bp, cache_b, cross_b = xs
        if block_constraint is not None:
            bp = jax.tree.map(
                lambda w, c: w if c is None else
                jax.lax.with_sharding_constraint(w, c),
                bp, block_constraint,
                is_leaf=lambda x: x is None)
        eo = cross_b if cfg.arch_type == "encdec" and mode == "decode" else enc_out
        h, new_cache_b, aux = _apply_block(
            cfg, bp, h, positions, causal=causal, cache_b=cache_b,
            cache_index=cache_index, enc_out=eo, collect_kv=collect_kv,
            use_pallas=use_pallas, valid_len=valid_len)
        return h, (new_cache_b, aux)

    body = jax.checkpoint(body_inner) if remat else body_inner

    cache_blocks = cache["blocks"] if cache is not None else None
    cross_blocks = cache.get("cross") if (cache is not None and cfg.arch_type == "encdec") else None
    unroll_n = n_blocks(cfg) if (unroll is True or unroll == 0) else int(unroll)
    if cache_blocks is None:
        h, (new_blocks, aux_stack) = jax.lax.scan(
            lambda c, bp: body(c, (bp, None, None)), h, params["blocks"],
            unroll=unroll_n)
        new_cache = None
        if collect_kv:
            new_cache = {"blocks": new_blocks}
            if cfg.arch_type == "encdec":
                hd = cfg.resolved_head_dim

                def cross_kv(block_p):
                    sp = block_p["slot0"]["cross"]
                    kk = L.matmul(enc_out, sp["wk"]).reshape(b, -1, cfg.n_kv_heads, hd)
                    vv = L.matmul(enc_out, sp["wv"]).reshape(b, -1, cfg.n_kv_heads, hd)
                    return {"k": kk, "v": vv}

                new_cache["cross"] = jax.lax.map(cross_kv, params["blocks"])
    elif cross_blocks is None:
        h, (new_blocks, aux_stack) = jax.lax.scan(
            lambda c, x: body(c, (x[0], x[1], None)), h,
            (params["blocks"], cache_blocks), unroll=unroll_n)
        new_cache = dict(cache)
        new_cache["blocks"] = new_blocks
    else:
        h, (new_blocks, aux_stack) = jax.lax.scan(
            body, h, (params["blocks"], cache_blocks, cross_blocks),
            unroll=unroll_n)
        new_cache = dict(cache)
        new_cache["blocks"] = new_blocks

    aux = {k: jnp.sum(v) for k, v in aux_stack.items()} if aux_stack else {}

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    out = {"cache": new_cache, "aux": aux, "hidden": h}
    if cfg.objective == "diffusion" and embeds is not None:
        out["eps"] = L.matmul(h, params["eps_head"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jax.lax.dot_general(h, head, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    out["logits"] = logits
    return out
