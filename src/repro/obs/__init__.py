"""Observability subsystem: metrics registry, span tracing, exporters, and
the ``BENCH_*.json`` perf-trajectory recorder.

The layer every perf/robustness PR reports through:

* :mod:`repro.obs.metrics` -- thread-aware registry of counters / gauges /
  histograms with a lock-free fast path and a consistent ``snapshot()``;
* :mod:`repro.obs.trace`   -- nestable span timers (engine ticks, group
  steps, AOT compiles, join/compact boundaries) with optional
  ``jax.profiler.TraceAnnotation`` pass-through so spans land in XLA
  profiles;
* :mod:`repro.obs.export`  -- Prometheus-text and NDJSON renderers over a
  registry snapshot;
* :mod:`repro.obs.bench`   -- ``BENCH_*.json`` records (run metadata +
  named metric series) plus the ``compare()`` ratchet that fails on
  regression beyond a per-metric tolerance.

See ``docs/observability.md`` for the metric catalog, span hierarchy,
BENCH schema and ratchet workflow.
"""
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Tracer, NULL_TRACER

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "Tracer", "NULL_TRACER"]
