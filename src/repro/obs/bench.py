"""``BENCH_*.json`` perf-trajectory records and the regression ratchet.

A bench record is one JSON document per benchmark run::

    {
      "schema": "bench.v1",
      "name": "serving",
      "created": 1754560000.0,          # unix seconds (wall-clock label)
      "meta": {"quick": true, "backend": "cpu", "jax": "0.4.37", ...},
      "metrics": {
        "continuous_admission.joins_on.wasted_row_steps": {
            "value": 0.0, "unit": "steps", "direction": "lower",
            "ratchet": true, "tol": 0.0},
        "throughput.tab3_nfe10.us_per_request": {
            "value": 51234.2, "unit": "us", "direction": "lower",
            "ratchet": false}
      }
    }

Ratchet semantics (:func:`compare`): for every metric present in BOTH
records with ``ratchet: true``, the current value may not regress past the
baseline by more than the metric's tolerance (``tol``, a relative fraction;
the CLI ``--tol`` is the default for metrics that carry none):

* ``direction: "lower"``  -- regression when ``cur > base * (1 + tol)``
  (plus an absolute epsilon so a 0.0 baseline tolerates float noise);
* ``direction: "higher"`` -- regression when ``cur < base * (1 - tol)``.

Deterministic scheduler metrics (wasted steps, warm recompiles, tick-counted
queue waits) ratchet at ``tol: 0`` -- any drift fails. Wall-clock timings
are recorded with ``ratchet: false`` by default: they accumulate the
trajectory without making CI flaky across machines; flip them on (with a
generous tol) on a pinned benchmark host. A record always compares clean
against itself, which is what CI's perf-smoke job asserts before ratcheting
against the committed baseline.

CLI::

    python -m repro.obs.bench show BENCH_serving.json
    python -m repro.obs.bench compare BASELINE.json CURRENT.json [--tol 0.1]

``compare`` exits non-zero on any regression (the CI failure signal) and
prints one line per compared metric.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Optional

SCHEMA = "bench.v1"
# absolute slack added on top of the relative tolerance so integer-zero
# baselines (wasted_row_steps == 0) do not demand bit-equality of floats
_ABS_EPS = 1e-9


def metric(value: float, *, unit: str = "", direction: str = "lower",
           ratchet: bool = False, tol: Optional[float] = None) -> dict:
    """One metric entry. ``direction`` is which way is BETTER."""
    if direction not in ("lower", "higher"):
        raise ValueError(f"direction must be 'lower' or 'higher', "
                         f"got {direction!r}")
    out = {"value": float(value), "unit": unit, "direction": direction,
           "ratchet": bool(ratchet)}
    if tol is not None:
        out["tol"] = float(tol)
    return out


def record(name: str, metrics: dict, meta: Optional[dict] = None) -> dict:
    """Assemble a bench record (adds schema/name/created/meta envelope)."""
    return {"schema": SCHEMA, "name": name, "created": time.time(),
            "meta": dict(meta or {}), "metrics": dict(metrics)}


def write(path: str, rec: dict) -> None:
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, sort_keys=True)
        f.write("\n")


def load(path: str) -> dict:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("schema") != SCHEMA:
        raise ValueError(f"{path}: unsupported bench schema "
                         f"{rec.get('schema')!r} (want {SCHEMA!r})")
    return rec


@dataclasses.dataclass
class Comparison:
    """One metric's baseline-vs-current verdict."""
    name: str
    base: float
    cur: float
    direction: str
    tol: float
    ratcheted: bool
    regressed: bool

    def line(self) -> str:
        tag = ("REGRESSED" if self.regressed else
               "ok" if self.ratcheted else "info")
        return (f"  [{tag:>9}] {self.name}: {self.base:g} -> {self.cur:g} "
                f"({self.direction} is better, tol={self.tol:g})")


def compare(baseline: dict, current: dict,
            default_tol: float = 0.0) -> list[Comparison]:
    """Compare two bench records; see the module docstring for semantics.

    Only metrics present in BOTH records are compared (a new metric starts
    its trajectory without failing the ratchet; a dropped one should be
    caught in review of the baseline file). Returns one
    :class:`Comparison` per shared metric; ``regressed`` is only ever True
    for ratcheted metrics."""
    out = []
    for name in sorted(set(baseline["metrics"]) & set(current["metrics"])):
        b, c = baseline["metrics"][name], current["metrics"][name]
        direction = b.get("direction", "lower")
        tol = float(b.get("tol", default_tol))
        ratcheted = bool(b.get("ratchet", False))
        bv, cv = float(b["value"]), float(c["value"])
        if direction == "lower":
            bad = cv > bv * (1.0 + tol) + _ABS_EPS
        else:
            bad = cv < bv * (1.0 - tol) - _ABS_EPS
        out.append(Comparison(name=name, base=bv, cur=cv,
                              direction=direction, tol=tol,
                              ratcheted=ratcheted,
                              regressed=ratcheted and bad))
    return out


def regressions(comps: list[Comparison]) -> list[Comparison]:
    return [c for c in comps if c.regressed]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.obs.bench")
    sub = ap.add_subparsers(dest="cmd", required=True)
    ps = sub.add_parser("show", help="pretty-print a bench record")
    ps.add_argument("path")
    pc = sub.add_parser("compare",
                        help="ratchet CURRENT against BASELINE; exit 1 on "
                             "regression beyond tolerance")
    pc.add_argument("baseline")
    pc.add_argument("current")
    pc.add_argument("--tol", type=float, default=0.0,
                    help="default relative tolerance for ratcheted metrics "
                         "that carry none (default 0)")
    args = ap.parse_args(argv)

    if args.cmd == "show":
        rec = load(args.path)
        print(f"{rec['name']} (created {rec['created']}) meta={rec['meta']}")
        for name in sorted(rec["metrics"]):
            m = rec["metrics"][name]
            flag = "ratchet" if m.get("ratchet") else "info"
            print(f"  [{flag:>7}] {name} = {m['value']:g} {m.get('unit', '')}")
        return 0

    base, cur = load(args.baseline), load(args.current)
    if base.get("meta", {}).get("quick") != cur.get("meta", {}).get("quick"):
        print("warning: comparing records from different quick/full modes; "
              "metric values are not commensurate", file=sys.stderr)
    comps = compare(base, cur, default_tol=args.tol)
    print(f"compared {len(comps)} shared metrics "
          f"({sum(c.ratcheted for c in comps)} ratcheted):")
    for c in comps:
        print(c.line())
    bad = regressions(comps)
    if bad:
        print(f"\n{len(bad)} metric(s) regressed beyond tolerance",
              file=sys.stderr)
        return 1
    print("ratchet clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
