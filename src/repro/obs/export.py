"""Render a :class:`~repro.obs.metrics.MetricsRegistry` snapshot.

Two formats:

* :func:`to_prometheus` -- the Prometheus text exposition format (0.0.4):
  ``# HELP``/``# TYPE`` headers, ``_bucket{le="..."}`` cumulative series +
  ``_sum``/``_count`` for histograms. This is what the serving launcher's
  ``GET /metrics`` endpoint returns.
* :func:`to_ndjson_line` / :class:`NdjsonExporter` -- one JSON object per
  snapshot (timestamped), appended as a line to a file. NDJSON is the
  offline twin of /metrics: point a ``--metrics-ndjson PATH`` run at a file
  and every snapshot interval adds one greppable line.
"""
from __future__ import annotations

import json
import time
from typing import Optional

from .metrics import Counter, Gauge, Histogram, MetricsRegistry


def _fmt(v: float) -> str:
    """Prometheus float formatting: integers without a trailing .0."""
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def to_prometheus(registry: MetricsRegistry) -> str:
    """Render the registry in the Prometheus text exposition format."""
    lines: list[str] = []
    for m in registry:
        if isinstance(m, Counter):
            lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} counter")
            lines.append(f"{m.name} {_fmt(m.value)}")
        elif isinstance(m, Gauge):
            lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} gauge")
            lines.append(f"{m.name} {_fmt(m.value)}")
        elif isinstance(m, Histogram):
            lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} histogram")
            cum = m.cumulative()
            for edge, c in zip(m.edges, cum):
                lines.append(f'{m.name}_bucket{{le="{_fmt(edge)}"}} {c}')
            lines.append(f'{m.name}_bucket{{le="+Inf"}} {cum[-1]}')
            lines.append(f"{m.name}_sum {_fmt(m.sum)}")
            lines.append(f"{m.name}_count {m.count}")
    return "\n".join(lines) + "\n"


def to_ndjson_line(registry: MetricsRegistry, *,
                   extra: Optional[dict] = None) -> str:
    """One NDJSON line: ``{"ts": <unix seconds>, "metrics": {...}}``.

    ``ts`` is wall-clock (``time.time()``) on purpose -- NDJSON lines are
    correlated with logs and dashboards across processes, where monotonic
    perf_counter origins differ. Durations INSIDE the metrics are all
    perf_counter-measured; only the snapshot label is wall-clock."""
    doc = {"ts": time.time(), "metrics": registry.snapshot()}
    if extra:
        doc.update(extra)
    return json.dumps(doc, sort_keys=True)


class NdjsonExporter:
    """Append-one-line-per-snapshot NDJSON writer.

    Opens lazily and appends, so several runs can share one trajectory
    file; ``write()`` is cheap enough to call per scrape or on a timer
    thread (one ``snapshot()`` + one buffered line)."""

    def __init__(self, path: str, *, extra: Optional[dict] = None):
        self.path = path
        self.extra = extra or {}
        self._fh = None

    def write(self, registry: MetricsRegistry) -> None:
        if self._fh is None:
            self._fh = open(self.path, "a")
        self._fh.write(to_ndjson_line(registry, extra=self.extra) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "NdjsonExporter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
