"""Thread-aware metrics registry: counters, gauges, histograms.

Design constraints (these are serving-hot-path objects):

* **Lock-free fast path.** ``Counter.inc`` / ``Gauge.set`` /
  ``Histogram.observe`` take no lock: each metric has ONE designated writer
  in the serving stack (the scheduler thread), so a plain read-modify-write
  under the GIL is race-free there. The few multi-writer sites (transport
  threads counting submits/sheds) already hold the driver's submit lock and
  increment inside it. Registration (``counter()``/``gauge()``/
  ``histogram()``) is the only locked operation -- it happens at
  construction time, never per step.
* **Consistent-enough snapshots.** ``snapshot()`` reads each metric's value
  without stopping writers: every individual value is a coherent Python
  object read, but values of *different* metrics may straddle a concurrent
  update (torn across metrics, never within one). For serving dashboards
  and the bench recorder that is the right trade -- a snapshot must never
  stall the scheduler.
* **Fixed histogram bucket edges.** Buckets are chosen at registration
  (``edges`` ascending, in seconds for the serving defaults) and never
  reshaped, so ``observe`` is a bisect + two adds and the Prometheus
  rendering is cumulative-by-construction.

Metric naming follows Prometheus conventions (``*_total`` counters,
``*_seconds`` histograms); the catalog the serving stack registers is
documented in ``docs/observability.md``.
"""
from __future__ import annotations

import bisect
import threading
from typing import Iterable, Optional

# default edges for serving latency-ish histograms (seconds): spans cold
# compiles (10s+) down to sub-ms scheduler work
DEFAULT_TIME_EDGES = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                      1.0, 2.5, 5.0, 10.0, 30.0)


class Counter:
    """Monotonic counter. ``inc`` is the lock-free fast path; ``reset`` is a
    test/benchmark affordance (warm-pass measurement re-zeroes engine
    counters) and intentionally NOT part of the Prometheus contract."""

    __slots__ = ("name", "help", "_value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self._value += v

    @property
    def value(self) -> float:
        return self._value

    def reset(self, v: float = 0.0) -> None:
        self._value = float(v)


class Gauge:
    """Last-write-wins instantaneous value (queue depth, occupancy)."""

    __slots__ = ("name", "help", "_value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self._value += v

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram: ``observe`` is bisect + two adds.

    ``edges`` are the ascending upper bounds of the finite buckets; an
    implicit ``+Inf`` bucket catches the tail. Counts are stored
    per-bucket (not cumulative) and cumulated at render time, so the hot
    path touches exactly one bucket slot."""

    __slots__ = ("name", "help", "edges", "_counts", "_sum", "_count")

    def __init__(self, name: str, help: str = "",
                 edges: Iterable[float] = DEFAULT_TIME_EDGES):
        edges = tuple(float(e) for e in edges)
        if not edges or list(edges) != sorted(set(edges)):
            raise ValueError(f"histogram edges must be strictly ascending "
                             f"and non-empty, got {edges!r}")
        self.name, self.help, self.edges = name, help, edges
        self._counts = [0] * (len(edges) + 1)   # +1: the +Inf bucket
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        self._counts[bisect.bisect_left(self.edges, v)] += 1
        self._sum += v
        self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def counts(self) -> list[int]:
        """Per-bucket (not cumulative) counts, +Inf bucket last. A copy."""
        return list(self._counts)

    def cumulative(self) -> list[int]:
        """Cumulative bucket counts aligned with ``edges`` + the +Inf tail
        (the Prometheus ``le`` series)."""
        out, acc = [], 0
        for c in self._counts:
            acc += c
            out.append(acc)
        return out

    def reset(self) -> None:
        self._counts = [0] * (len(self.edges) + 1)
        self._sum = 0.0
        self._count = 0


class MetricsRegistry:
    """A named set of metrics with idempotent registration.

    ``counter(name)`` etc. return the existing metric when the name is
    already registered (so independent call sites can share one series)
    and raise if the name is bound to a different metric type. All
    registration goes through one lock; reads and the per-metric fast
    paths never touch it.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _register(self, cls, name: str, *args, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args, **kw)
                self._metrics[name] = m
            elif type(m) is not cls:
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  edges: Iterable[float] = DEFAULT_TIME_EDGES) -> Histogram:
        return self._register(Histogram, name, help, edges)

    def get(self, name: str) -> Optional[object]:
        # repro: allow[RL003] GIL-atomic dict read; registration is the only writer
        return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        # repro: allow[RL003] GIL-atomic membership test, same contract as get()
        return name in self._metrics

    def __iter__(self):
        # snapshot the dict under the lock; iteration itself is lock-free
        with self._lock:
            items = list(self._metrics.values())
        return iter(items)

    def snapshot(self) -> dict:
        """Plain-data view of every metric (JSON-ready).

        Counters/gauges map to floats; histograms to
        ``{"edges", "counts", "sum", "count"}`` with per-bucket (not
        cumulative) counts. Each metric's value is read coherently;
        different metrics may straddle a concurrent update (see module
        docstring)."""
        out: dict = {}
        for m in self:
            if isinstance(m, Histogram):
                out[m.name] = {"edges": list(m.edges),
                               "counts": list(m._counts),
                               "sum": m._sum, "count": m._count}
            else:
                out[m.name] = m.value
        return out
