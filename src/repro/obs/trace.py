"""Nestable span timers for the serving stack's host-side phases.

A :class:`Tracer` times named spans -- engine ticks, group-step dispatch,
AOT compiles, join/compact boundary work -- and feeds each duration into a
per-span-name histogram of a :class:`~repro.obs.metrics.MetricsRegistry`.
Spans nest (``tick`` > ``admit`` > ``join``); the tracer keeps a thread-local
stack so the recorded name is the dotted path of its ancestry, which is what
``docs/observability.md`` documents as the span hierarchy.

Two hard rules, both about the jitted hot path:

* spans time HOST-side work only. A span around an executor call measures
  dispatch (and whatever the caller chooses to block on), never forces a
  device sync itself -- there is no ``block_until_ready`` anywhere in this
  module.
* with ``annotate=True`` each span also enters a
  ``jax.profiler.TraceAnnotation``, so the same span names show up attached
  to device work in XLA/perfetto profiles. The annotation is a no-op unless
  a profiler trace is being collected; it adds no sync either.

``NULL_TRACER`` is the disabled instance: its ``span()`` is a reusable
no-op context manager, so instrumented code never branches on "is tracing
on" -- it just always runs ``with tracer.span(...):``.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from .metrics import MetricsRegistry, DEFAULT_TIME_EDGES

try:  # pragma: no cover - depends on jax build
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover
    _TraceAnnotation = None


class _NullSpan:
    """Reusable no-op context manager (one shared instance, zero alloc)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One timed span: perf_counter on enter/exit, duration observed into
    the tracer's histogram for the span's dotted path. The parent path is
    carried explicitly (not recomputed from the dotted string) so span
    NAMES may themselves contain dots."""
    __slots__ = ("_tracer", "_path", "_parent", "_t0", "_ann")

    def __init__(self, tracer: "Tracer", path: str, parent: str):
        self._tracer = tracer
        self._path = path
        self._parent = parent
        self._ann = None

    def __enter__(self):
        tr = self._tracer
        tr._stack.path = self._path
        if tr.annotate and _TraceAnnotation is not None:
            self._ann = _TraceAnnotation(self._path)
            self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        tr = self._tracer
        if self._ann is not None:
            self._ann.__exit__(*exc)
        tr._observe(self._path, dt)
        tr._stack.path = self._parent
        return False


class Tracer:
    """Span-timer bound to a metrics registry.

    ``tracer.span("tick")`` inside ``tracer.span("serve")`` records into the
    histogram ``<prefix>span_seconds`` under the dotted path ``serve.tick``
    -- one histogram per distinct path, registered lazily. The nesting
    stack is thread-local, so transport threads and the scheduler thread
    can trace concurrently without mixing ancestries.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None, *,
                 prefix: str = "trace_", annotate: bool = False,
                 edges=DEFAULT_TIME_EDGES):
        self.registry = registry or MetricsRegistry()
        self.prefix = prefix
        self.annotate = annotate
        self._edges = edges
        self._stack = threading.local()
        self._stack.path = ""

    # thread-local access: a thread that never opened a span has no .path
    def _current(self) -> str:
        return getattr(self._stack, "path", "")

    def span(self, name: str) -> _Span:
        parent = self._current()
        return _Span(self, f"{parent}.{name}" if parent else name, parent)

    def _observe(self, path: str, dt: float) -> None:
        self.registry.histogram(
            f"{self.prefix}{path}_seconds",
            help=f"span duration: {path}", edges=self._edges).observe(dt)

    def span_names(self) -> list[str]:
        """Dotted span paths recorded so far (for tests/docs)."""
        pre, suf = self.prefix, "_seconds"
        return sorted(m.name[len(pre):-len(suf)] for m in self.registry
                      if m.name.startswith(pre) and m.name.endswith(suf))


class _NullTracer(Tracer):
    """Disabled tracer: ``span()`` returns a shared no-op context manager."""

    def __init__(self):
        super().__init__(MetricsRegistry())

    def span(self, name: str):  # type: ignore[override]
        return _NULL_SPAN


NULL_TRACER = _NullTracer()
