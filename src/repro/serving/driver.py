"""Async request-transport driver around :class:`DiffusionServeEngine`.

The engine is a synchronous scheduler: ``submit()`` enqueues, ``tick()``
advances. :class:`ServeDriver` turns that into a *service*: a dedicated
executor thread owns the engine and runs the tick loop, while any number of
transport threads (HTTP handlers, asyncio tasks, tests) hand requests over a
thread-safe inbox and get back a :class:`ServeStream` -- a per-request
future for the final :class:`~repro.serving.engine.Result` plus an ordered
stream of :class:`~repro.serving.engine.StepEvent` progress (optionally with
partial decodes).

Threading contract
------------------

* ONE thread (the driver's) ever touches the engine and therefore JAX.
  Transports only enqueue (``queue.Queue``) and wait on futures, so no JAX
  object crosses threads and no locking of engine state is needed.
* ``submit()`` is thread-safe and non-blocking; ``submit_async()`` is its
  asyncio twin (the returned handle supports ``async for`` over events and
  ``await handle.result()``).
* Per-request event fan-out happens on the scheduler thread between solver
  steps (the engine's ``on_step`` contract): each event is sliced down to
  the request's own row and progress (``k`` capped at the request's true
  step count in a ragged group) and pushed to that request's stream.

Ordering/reproducibility guarantee: the driver adds no randomness and never
reorders a request's own events; samples remain a pure function of
``(solver, nfe, eta, seed, seq_len)`` exactly as in the synchronous engine
-- priorities, deadlines, admission timing and compaction only change WHEN
steps run (see the engine module docstring).

Failure contract: engine-side validation errors (unknown solver, ddim_eta
without eta) are caught on the scheduler thread and delivered to the ONE
offending request's future as the original exception; other in-flight
requests are unaffected (contrast with the synchronous ``serve()``'s
all-or-nothing batch validation).

Backpressure contract: with ``max_pending=n`` the driver bounds its
in-flight set (submitted but unfinished requests). The (n+1)-th concurrent
submission is shed in O(1) at submit time: its handle's future fails with
:class:`QueueFull` and its event stream closes empty; nothing is enqueued,
the scheduler never sees it, and every admitted request proceeds untouched.
Both ``submit`` and ``submit_async`` shed identically.
"""
from __future__ import annotations

import asyncio
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Iterator, Optional

from .engine import (Cancelled, DeadlineExceeded, DiffusionServeEngine,
                     Request, Result, StepEvent)

_CLOSE = object()   # stream sentinel: no more events
_CANCEL = object()  # inbox sentinel: (sentinel, uid) cancellation order


class QueueFull(RuntimeError):
    """Raised on a request's handle when the driver sheds it for backpressure.

    Delivered through the rejected request's own :class:`ServeStream` future
    (``handle.result()`` re-raises it; the event stream closes empty) -- the
    driver itself never crashes and every other in-flight request is
    unaffected. Clients treat it like HTTP 429: back off and resubmit.
    """


class ServeStream:
    """Per-request handle: an event stream plus a future for the Result.

    Iterating (``for ev in stream``) yields :class:`StepEvent`\\ s scoped to
    THIS request (``uids == (uid,)``, ``n_steps`` = the request's own step
    count, ``tokens`` = its own row when the driver streams decodes) and
    ends when the request finishes or fails. ``result()`` blocks for the
    final :class:`Result` (or re-raises the request's validation error).
    Both may be consumed from any thread, together or independently.
    """

    def __init__(self, uid: int):
        self.uid = uid
        self._events: queue.Queue = queue.Queue()
        self._future: Future = Future()

    # ---- producer side (driver thread) ----
    def _push(self, event: StepEvent) -> None:
        self._events.put(event)

    def _finish(self, result: Result) -> None:
        if self._future.done():           # already failed (e.g. by _crash)
            return
        self._future.set_result(result)   # result first: visible the moment
        self._events.put(_CLOSE)          # ... iteration ends

    def _fail(self, exc: BaseException) -> None:
        if self._future.done():
            return
        self._future.set_exception(exc)
        self._events.put(_CLOSE)

    # ---- consumer side (any thread) ----
    def result(self, timeout: Optional[float] = None) -> Result:
        """Block until the request finishes; raises its validation error."""
        return self._future.result(timeout)

    def done(self) -> bool:
        """True once the request has finished or failed."""
        return self._future.done()

    def events(self) -> Iterator[StepEvent]:
        """Yield this request's StepEvents in order until completion."""
        while True:
            ev = self._events.get()
            if ev is _CLOSE:
                return
            yield ev

    def __iter__(self) -> Iterator[StepEvent]:
        return self.events()


class AsyncServeStream:
    """Asyncio view of a :class:`ServeStream`.

    ``async for ev in handle`` iterates events; ``await handle.result()``
    awaits the final Result. Event waits are delegated to a worker thread
    (``asyncio.to_thread``) so the loop is never blocked by the scheduler.
    """

    def __init__(self, stream: ServeStream):
        self._stream = stream
        self.uid = stream.uid

    def __aiter__(self):
        return self

    async def __anext__(self) -> StepEvent:
        # Cancellation-safe: poll with non-blocking gets + short sleeps
        # instead of parking a worker thread in Queue.get() -- a cancelled
        # to_thread future leaves its thread blocked, and that orphan would
        # later swallow the next event (or the close sentinel). Solver steps
        # are O(10ms+), so a few-ms poll adds no measurable latency.
        while True:
            try:
                ev = self._stream._events.get_nowait()
            except queue.Empty:
                await asyncio.sleep(0.002)
                continue
            if ev is _CLOSE:
                raise StopAsyncIteration
            return ev

    async def result(self) -> Result:
        """Await the final Result (re-raises the request's validation error)."""
        return await asyncio.wrap_future(self._stream._future)

    def done(self) -> bool:
        """True once the request has finished or failed."""
        return self._stream.done()


class ServeDriver:
    """Run a :class:`DiffusionServeEngine` on a dedicated scheduler thread.

    Usage (sync transport)::

        with ServeDriver(engine, stream_decode=True) as drv:
            h = drv.submit(Request(uid=0, seq_len=32, nfe=10, solver="tab3"))
            for ev in h:                      # streamed progress
                print(ev.k, "/", ev.n_steps)
            tokens = h.result().tokens

    Usage (asyncio transport)::

        h = await drv.submit_async(Request(...))
        async for ev in h: ...
        res = await h.result()

    The driver is the natural place to throttle the scheduler for latency:
    construct the engine with ``steps_per_tick=k`` and the driver's tick
    loop becomes earliest-deadline-first over in-flight groups (with
    starvation aging), admitting newly transported requests at every step
    boundary.
    """

    def __init__(self, engine: DiffusionServeEngine, *,
                 stream_decode: bool = False, idle_wait_s: float = 0.005,
                 max_pending: int | None = None):
        """``max_pending``: bound on in-flight requests (submitted, not yet
        finished). ``None`` = unbounded (the pre-backpressure behavior).
        Submissions over the bound are shed instantly: the returned handle's
        future fails with :class:`QueueFull` and nothing reaches the
        scheduler thread, so an ingest burst can neither grow the inbox
        without limit nor crash the driver."""
        self.engine = engine
        self.stream_decode = stream_decode
        self.idle_wait_s = idle_wait_s
        self.max_pending = max_pending
        self._inbox: queue.Queue = queue.Queue()
        self._streams: dict[int, ServeStream] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # Driver metrics live in the ENGINE's registry so one /metrics scrape
        # (or NDJSON snapshot) covers the whole serving stack.
        self.metrics = engine.metrics
        self._m_submitted = self.metrics.counter(
            "driver_submitted_total", help="requests accepted by the driver")
        self._m_shed = self.metrics.counter(
            "driver_shed_total",
            help="requests shed at submit time (QueueFull backpressure)")
        self._h_loop = self.metrics.histogram(
            "driver_loop_seconds",
            help="scheduler-loop iteration latency (drain + tick + fanout)")

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ServeDriver":
        """Start the scheduler thread (idempotent).

        The check-then-spawn runs under ``_lock``: two concurrent first
        ``submit()`` calls would otherwise both see ``_thread is None`` and
        start two scheduler threads over a single-threaded engine."""
        with self._lock:
            if self._thread is None or not self._thread.is_alive():
                self._stop.clear()
                self._thread = threading.Thread(
                    target=self._run, name="deis-serve-driver", daemon=True)
                self._thread.start()
        return self

    def stop(self, timeout: Optional[float] = None) -> None:
        """Drain: finish everything submitted, then stop the thread.

        If ``timeout`` expires while the scheduler is still mid-solve the
        thread reference is KEPT, so a later ``submit()``/``start()`` cannot
        spawn a second scheduler thread over a live one (the engine is
        single-threaded by contract)."""
        self._stop.set()
        with self._lock:
            thread = self._thread
        if thread is not None:
            thread.join(timeout)  # join outside the lock: submit() must not
            if not thread.is_alive():  # block behind a draining scheduler
                with self._lock:
                    if self._thread is thread:
                        self._thread = None

    def __enter__(self) -> "ServeDriver":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------ transport
    def submit(self, request: Request) -> ServeStream:
        """Thread-safe, non-blocking submission; returns the request handle.

        ``request.uid`` must be unique among in-flight requests (it keys the
        event fan-out). Validation happens on the scheduler thread; errors
        surface on the returned handle, not here. Backpressure also surfaces
        on the handle: over ``max_pending`` in-flight requests, the handle
        comes back already failed with :class:`QueueFull` (fast shed -- the
        request never touches the scheduler)."""
        stream = ServeStream(request.uid)
        with self._lock:
            if request.uid in self._streams:
                raise ValueError(f"request uid {request.uid} is already "
                                 "in flight")
            if self.max_pending is not None and \
                    len(self._streams) >= self.max_pending:
                self._m_shed.inc()
                stream._fail(QueueFull(
                    f"driver at max_pending={self.max_pending} in-flight "
                    f"requests; request uid {request.uid} shed -- back off "
                    "and resubmit"))
                return stream
            self._streams[request.uid] = stream
            self._m_submitted.inc()
        self._inbox.put((request, stream))
        # start AFTER the put: if a concurrent stop() let the scheduler
        # thread observe (stop set, inbox empty) and exit between our
        # registration and the put, this restarts it and the new thread
        # drains the inbox -- no request can be stranded with an unresolved
        # future. (start() is idempotent while the thread lives.)
        self.start()
        return stream

    async def submit_async(self, request: Request) -> AsyncServeStream:
        """Asyncio twin of :meth:`submit` (same queue, same guarantees)."""
        return AsyncServeStream(self.submit(request))

    def cancel(self, uid: int) -> bool:
        """Request cancellation of an in-flight request (thread-safe,
        non-blocking, best-effort).

        The order rides the SAME inbox as submissions, so it can never
        outrun its own request: by the time the scheduler processes it, the
        request has been handed to the engine (FIFO), and
        ``engine.cancel`` either drops it from pending or retires its
        mid-flight row through the deadline-eviction machinery. The
        request's handle then fails with :class:`Cancelled` (partial Result
        attached) and its event stream closes -- the same per-request
        failure shape as a deadline eviction.

        Returns True when ``uid`` was in flight at call time; False is a
        no-op (already finished, shed, or never submitted -- any
        already-delivered Result stands). Cancellation that loses the race
        with the request's own completion is also a no-op: the sample wins.
        """
        with self._lock:
            live = uid in self._streams
        if live:
            self._inbox.put((_CANCEL, uid))
            self.start()
        return live

    def stats(self) -> dict:
        """Scheduler counters (safe snapshot; values may lag one tick).

        All counts come from the shared metrics registry (engine + driver
        write into the same one); the historical keys are kept so existing
        callers and the HTTP ``/stats`` route are unaffected."""
        eng = self.engine
        with self._lock:
            in_flight = len(self._streams)
        return {"ticks": eng.ticks, "executors": eng.num_executors,
                "wasted_row_steps": eng.wasted_row_steps,
                "joined_requests": eng.joined_requests,
                "in_flight": in_flight,
                "max_pending": self.max_pending,
                "submitted": int(self._m_submitted.value),
                "shed": int(self._m_shed.value),
                "completed": int(eng._m_completed.value),
                "deadline_evicted": int(eng._m_evicted.value),
                "cancelled": int(eng._m_cancelled.value),
                "early_exit": int(eng._m_early.value),
                "saved_nfe": int(eng._m_saved_nfe.value)}

    # ------------------------------------------------------------ scheduler
    def _drain_inbox(self, block: bool) -> None:
        try:
            first = self._inbox.get(timeout=self.idle_wait_s) if block \
                else self._inbox.get_nowait()
        except queue.Empty:
            return
        batch = [first]
        while True:
            try:
                batch.append(self._inbox.get_nowait())
            except queue.Empty:
                break
        for req, stream in batch:
            if req is _CANCEL:
                # stream here is the uid; engine emits the cancelled Result
                # at the next tick (False = already finished: no-op, the
                # delivered Result stands)
                self.engine.cancel(stream)
                continue
            try:
                self.engine.submit(req)
            except Exception as e:  # per-request failure, not batch-fatal
                with self._lock:
                    self._streams.pop(req.uid, None)
                stream._fail(e)

    def _fanout(self, event: StepEvent) -> None:
        """Engine ``on_step`` callback: slice the group event per request.

        ``row_k`` carries each request's OWN completed step count (a joiner
        spliced into an in-flight group counts from its admission tick), and
        ``row_seq_lens`` its true length (bucketed admission solves at the
        bucket edge; streamed decodes are masked back to the request)."""
        for i, uid in enumerate(event.uids):
            with self._lock:
                stream = self._streams.get(uid)
            if stream is None:
                continue   # submitted directly to the engine, or finished
            row_n = event.row_steps[i] if event.row_steps else event.n_steps
            row_k = event.row_k[i] if event.row_k else event.k
            if row_k > row_n:
                continue   # retired row still riding an uncompacted group
            tok = event.tokens[i] if event.tokens is not None else None
            if tok is not None and event.row_seq_lens:
                tok = tok[:event.row_seq_lens[i]]
            err = (event.row_err[i],) if event.row_err is not None else None
            stream._push(dataclasses.replace(
                event, uids=(uid,), k=min(row_k, row_n), n_steps=row_n,
                tokens=tok, row_steps=None, row_k=None, row_seq_lens=None,
                row_err=err))

    def _crash(self, exc: BaseException) -> None:
        """A tick blew up: the engine's in-flight state is unreliable, so
        fail EVERY in-flight request with the error (no silent thread death,
        no futures stranded forever) and reset the scheduler queues --
        including requests still in the inbox, which are drained and failed
        too (their streams are already registered; leaving them queued would
        resubmit them against their already-failed futures). The driver
        keeps serving later submissions."""
        with self._lock:
            streams, self._streams = self._streams, {}
        while True:
            try:
                self._inbox.get_nowait()
            except queue.Empty:
                break
        self.engine.reset()
        for stream in streams.values():
            stream._fail(exc)

    def _run(self) -> None:
        while True:
            busy = self.engine.busy
            self._drain_inbox(block=not busy)
            if self.engine.busy:
                t0 = time.perf_counter()
                try:
                    results = self.engine.tick(
                        on_step=self._fanout,
                        stream_decode=self.stream_decode)
                except Exception as e:   # noqa: BLE001 - fail open, keep serving
                    self._crash(e)
                    continue
                for res in results:
                    with self._lock:
                        stream = self._streams.pop(res.uid, None)
                    if stream is None:
                        continue
                    if res.cancelled:
                        exc = Cancelled(
                            f"request uid {res.uid} cancelled after "
                            f"{res.latency_s:.3f}s of solve time")
                        exc.result = res
                        stream._fail(exc)
                    elif res.deadline_exceeded:
                        # Deadline eviction is a per-request outcome, never a
                        # driver crash: the engine recycled the row and this
                        # request's own future carries the error (with the
                        # partial Result attached for latency accounting).
                        exc = DeadlineExceeded(
                            f"request uid {res.uid} evicted: absolute "
                            f"deadline passed after {res.latency_s:.3f}s of "
                            "solve time")
                        exc.result = res
                        stream._fail(exc)
                    else:
                        stream._finish(res)
                self._h_loop.observe(time.perf_counter() - t0)
            elif self._stop.is_set() and self._inbox.empty():
                return
