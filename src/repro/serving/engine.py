"""Batched serving engines.

ARServeEngine      : classic prefill + KV-cache decode loop over a request
                     queue (continuous slot-based batching).
DiffusionServeEngine: the paper's workload -- batched DEIS sampling requests.
                     Requests asking for the same (solver, NFE, seq_len) are
                     batched into one embedding-space ODE solve; each NFE is
                     one full-sequence backbone forward. This is where DEIS's
                     small-NFE advantage becomes throughput: serving capacity
                     scales ~1/NFE.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import get_timesteps, make_plan
from ..core.plan import SolverPlan
from ..core.sde import SDE, VPSDE
from ..diffusion import lm as DLM
from ..models import transformer as T
from ..training.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray | None = None       # AR: token prompt
    max_new_tokens: int = 32
    seq_len: int = 64                      # diffusion: sample length
    nfe: int = 10
    solver: str = "tab3"
    eta: float | None = None               # required iff solver == "ddim_eta"
    seed: int = 0


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray
    latency_s: float
    nfe: int = 0


class ARServeEngine:
    """Slot-based continuous batching: up to ``max_batch`` concurrent decodes."""

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 512):
        self.params, self.cfg = params, cfg
        self.max_batch, self.max_len = max_batch, max_len
        self._decode = jax.jit(make_decode_step(cfg))
        self._prefill = jax.jit(make_prefill_step(cfg))

    def serve(self, requests: list[Request], extras_fn=None) -> list[Result]:
        """Run all requests to completion; returns Results (greedy decode)."""
        cfg = self.cfg
        results: list[Result] = []
        queue = list(requests)
        # static single-sequence path batched over slots sequentially -- a
        # deliberately simple, correct reference loop (throughput benchmarks
        # jit the batched decode path directly).
        for req in queue:
            t0 = time.time()
            extras = extras_fn(req) if extras_fn else {}
            prompt = jnp.asarray(req.prompt)[None]
            batch = {"tokens": prompt, **extras}
            logits, cache = self._prefill(self.params, batch)
            # grow cache to max_len
            def grow(leaf):
                if leaf.ndim >= 3 and leaf.shape[2] == prompt.shape[1] and not (
                        cfg.sliding_window and leaf.shape[2] == cfg.sliding_window):
                    pad = [(0, 0)] * leaf.ndim
                    pad[2] = (0, self.max_len - leaf.shape[2])
                    return jnp.pad(leaf, pad)
                return leaf
            cache = dict(cache)
            cache["blocks"] = jax.tree.map(grow, cache["blocks"])
            tok = jnp.argmax(logits, -1)[:, None]
            out_tokens = [int(tok[0, 0])]
            pos = prompt.shape[1]
            for _ in range(req.max_new_tokens - 1):
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.int32(pos))
                tok = jnp.argmax(logits, -1)[:, None]
                out_tokens.append(int(tok[0, 0]))
                pos += 1
            results.append(Result(req.uid, np.asarray(out_tokens),
                                  time.time() - t0))
        return results


class DiffusionServeEngine:
    """Batched DEIS sampling service (the paper's technique as a server).

    Plans are data, not code: each (solver, nfe) pair builds one immutable
    ``SolverPlan`` (cached host-side), and the jitted executor takes the plan
    as a *traced* pytree argument. The compile cache is therefore keyed on
    ``(plan.signature, batch, seq_len)`` -- every solver name whose plan has
    the same step method and coefficient shapes (e.g. ddim / euler /
    naive_ei at equal NFE, or em / ddim_eta, or ipndm-r / tab-r) reuses one
    compiled executor instead of exploding the jit cache across all 20
    solver names x NFE settings.
    """

    def __init__(self, params, cfg: ModelConfig, sde: Optional[SDE] = None,
                 schedule: str = "quadratic"):
        assert cfg.objective == "diffusion"
        self.params, self.cfg = params, cfg
        self.sde = sde or VPSDE()
        self.schedule = schedule
        self._plans: dict = {}      # (solver, nfe, eta) -> SolverPlan
        self._compiled: dict = {}   # (plan.signature, batch, seq_len) -> jitted fn

    def _plan(self, solver: str, nfe: int, eta: float | None) -> SolverPlan:
        if solver == "ddim_eta" and eta is None:
            raise ValueError("Request(solver='ddim_eta') requires an explicit "
                             "eta= (eta=0 deterministic, eta=1 ancestral)")
        key_ = (solver, nfe, eta)
        if key_ not in self._plans:
            ts = get_timesteps(self.sde, nfe, self.schedule)
            kw = {"eta": eta} if solver == "ddim_eta" else {}
            self._plans[key_] = make_plan(solver, self.sde, ts, **kw)
        return self._plans[key_]

    def _executor(self, plan: SolverPlan, batch: int, seq_len: int):
        key_ = (plan.signature, batch, seq_len)
        if key_ not in self._compiled:
            prior_std = self.sde.prior_std()

            def run(params, plan_arg, rng):
                return DLM.sample_tokens(params, self.cfg, plan_arg, rng,
                                         batch=batch, seq_len=seq_len,
                                         prior_std=prior_std)[0]

            self._compiled[key_] = jax.jit(run)
        return self._compiled[key_]

    def serve(self, requests: list[Request]) -> list[Result]:
        """Group by (solver, nfe, seq_len[, eta]) and run one batched solve each."""
        groups = defaultdict(list)
        for r in requests:
            # eta only distinguishes ddim_eta plans; don't split batchable
            # groups of other solvers on an ignored field
            eta = r.eta if r.solver == "ddim_eta" else None
            groups[(r.solver, r.nfe, r.seq_len, eta)].append(r)
        results = []
        for (solver, nfe, seq_len, eta), reqs in groups.items():
            t0 = time.time()
            plan = self._plan(solver, nfe, eta)
            fn = self._executor(plan, len(reqs), seq_len)
            rng = jax.random.PRNGKey(reqs[0].seed)
            toks = np.asarray(fn(self.params, plan, rng))
            dt = time.time() - t0
            for i, r in enumerate(reqs):
                results.append(Result(r.uid, toks[i], dt, nfe=plan.nfe))
        return results
