"""Batched serving engines.

ARServeEngine      : classic prefill + KV-cache decode loop over a request
                     queue (continuous slot-based batching).
DiffusionServeEngine: the paper's workload as a *streaming continuous-batching*
                     service over the pure ``step()`` executor.

Diffusion serving semantics
---------------------------

Admission.  ``submit()`` enqueues; at every scheduler ``tick()`` pending
requests are admitted into *groups* at a step boundary. A group stacks up to
``max_group`` requests whose plans share one :attr:`SolverPlan.family` and
whose ``seq_len`` matches -- solver *names* may differ (ddim / euler /
naive_ei stack into a single solve via :func:`repro.core.plan.stack_plans`)
and so may NFE budgets: shorter plans are padded to the bucket's longest
grid with :func:`repro.core.plan.pad_plan` (*ragged* groups). Each request
gets its own PRNG key derived from its own ``Request.seed``, so samples are
per-request reproducible regardless of batch composition, admission time, or
compaction. Requests never join a group mid-solve; they form a new group
that is interleaved with the groups already in flight.

Scheduling.  A tick selects up to ``steps_per_tick`` groups (default: all)
and advances each by ONE solver step, so a newly admitted 5-NFE request
starts making progress immediately instead of waiting behind a 50-NFE group.
Selection is priority/deadline-aware, not round-robin: groups are ordered by
effective priority (max member ``Request.priority``, boosted by one level
per ``aging_ticks`` consecutive skipped ticks -- starvation aging), then
earliest absolute deadline (min member ``submit time + deadline_s``; no
deadline sorts last), then admission order. With the default
``steps_per_tick=None`` every active group steps each tick and the ordering
only decides dispatch order; a throttled driver (``steps_per_tick=k``) gets
true earliest-deadline-first with guaranteed progress for starved work.

Completion & compaction.  Rows of a ragged group finish at their OWN step
count: a finished row's Result is emitted from that very tick (its latency
is the group's accumulated solve time so far), not when the whole group
drains. With ``compaction=True`` (default) the group is then *compacted*:
surviving rows are row-gathered (:func:`repro.core.plan.take_rows` +
:func:`repro.core.sampler.take_state_rows`) into a smaller
``(signature, batch, seq_len)`` bucket and keep stepping there, instead of
burning evals on retired rows. Compaction preserves bitwise per-request
reproducibility because every per-row quantity -- coefficients, iterate,
eps history, PRNG key chain -- moves whole. ``wasted_row_steps`` counts the
steps executed on already-finished rows (zero under compaction; the
no-compaction baseline pays one per dead row per tick).

Compile cache.  One jitted ``step`` is AOT-compiled per
``(plan.signature, batch, seq_len)`` and reused across groups, solver names
and step indices (``k`` is a traced argument; pndm's warmup/tail split is a
``lax.cond``). Compaction looks its smaller batch up in the same cache, so a
steady-state workload (e.g. the warm half of ``benchmarks/deis_serving``)
runs with ZERO recompilation. ``Result.compile_s`` carries the trace+compile
cost charged to the group that needed the executor; ``Result.latency_s`` is
pure solve wall-time, so benchmark numbers are not poisoned by trace cost.

Callback contract.  ``serve(..., on_step=fn)`` invokes ``fn(StepEvent)``
after every group step with the group's uids and progress; with
``stream_decode=True`` the event also carries the partial decode of the
current iterate (streamed tokens). ``StepEvent.row_steps`` gives each
request's own total step count so per-request progress is well-defined in a
ragged group. The callback runs on the scheduler thread between steps --
keep it cheap or copy the event out (the async ``ServeDriver`` fans it out
to per-request streams).

Each NFE is one full-sequence backbone forward, so this is where DEIS's
small-NFE advantage becomes throughput: serving capacity scales ~1/NFE.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import get_timesteps, make_plan
from ..core import sampler as SAMPLER
from ..core.plan import SolverPlan, pad_plan, solver_stages, stack_plans, take_rows
from ..core.sde import SDE, VPSDE
from ..diffusion import lm as DLM
from ..models import transformer as T
from ..training.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    """One serving request (AR or diffusion; diffusion fields listed last).

    ``priority`` (higher = more urgent) and ``deadline_s`` (latency budget in
    seconds, relative to submit time; ``None`` = best-effort) feed the
    engine's priority/deadline-aware scheduler. They influence WHEN a
    request is stepped, never WHAT it computes: samples depend only on
    ``(solver, nfe, eta, seed, seq_len)``.
    """
    uid: int
    prompt: np.ndarray | None = None       # AR: token prompt
    max_new_tokens: int = 32
    seq_len: int = 64                      # diffusion: sample length
    nfe: int = 10
    solver: str = "tab3"
    eta: float | None = None               # required iff solver == "ddim_eta"
    seed: int = 0
    priority: int = 0                      # scheduling weight (higher first)
    deadline_s: float | None = None        # latency budget from submit time


@dataclasses.dataclass
class Result:
    """Final per-request outcome. ``latency_s`` is the request's group solve
    time accumulated up to the tick ITS row finished (ragged rows finish
    early); ``nfe`` is the true evals its own plan spent (never the padded
    group's); ``compile_s`` is trace+compile charged to its group."""
    uid: int
    tokens: np.ndarray
    latency_s: float            # solve wall-time of the request's group,
                                # EXCLUDING compile/trace (see compile_s)
    nfe: int = 0                # true network evals spent (plan.nfe)
    compile_s: float = 0.0      # trace+compile charged to this group's
                                # executor; 0.0 on a warm compile cache


@dataclasses.dataclass
class StepEvent:
    """Per-step progress emitted to the ``on_step`` serving callback.

    In a ragged group ``n_steps`` is the LONGEST member's step count;
    ``row_steps[i]`` is request ``uids[i]``'s own total, so per-request
    progress is ``min(k, row_steps[i]) / row_steps[i]`` (this is what the
    driver reports on each request's stream).
    """
    uids: tuple                      # requests in the group that just stepped
    k: int                           # steps completed (1-based after the step)
    n_steps: int                     # total solver steps for this group
    tokens: Optional[np.ndarray] = None  # (R, seq_len) partial decode when
                                         # serve(stream_decode=True)
    row_steps: Optional[tuple] = None    # per-request true step counts
                                         # (aligned with uids)


class ARServeEngine:
    """Slot-based continuous batching: up to ``max_batch`` concurrent decodes."""

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 512):
        self.params, self.cfg = params, cfg
        self.max_batch, self.max_len = max_batch, max_len
        self._decode = jax.jit(make_decode_step(cfg))
        self._prefill = jax.jit(make_prefill_step(cfg))

    def serve(self, requests: list[Request], extras_fn=None) -> list[Result]:
        """Run all requests to completion; returns Results (greedy decode)."""
        cfg = self.cfg
        results: list[Result] = []
        queue = list(requests)
        # static single-sequence path batched over slots sequentially -- a
        # deliberately simple, correct reference loop (throughput benchmarks
        # jit the batched decode path directly).
        for req in queue:
            t0 = time.time()
            extras = extras_fn(req) if extras_fn else {}
            prompt = jnp.asarray(req.prompt)[None]
            batch = {"tokens": prompt, **extras}
            logits, cache = self._prefill(self.params, batch)
            # grow cache to max_len
            def grow(leaf):
                if leaf.ndim >= 3 and leaf.shape[2] == prompt.shape[1] and not (
                        cfg.sliding_window and leaf.shape[2] == cfg.sliding_window):
                    pad = [(0, 0)] * leaf.ndim
                    pad[2] = (0, self.max_len - leaf.shape[2])
                    return jnp.pad(leaf, pad)
                return leaf
            cache = dict(cache)
            cache["blocks"] = jax.tree.map(grow, cache["blocks"])
            tok = jnp.argmax(logits, -1)[:, None]
            out_tokens = [int(tok[0, 0])]
            pos = prompt.shape[1]
            for _ in range(req.max_new_tokens - 1):
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.int32(pos))
                tok = jnp.argmax(logits, -1)[:, None]
                out_tokens.append(int(tok[0, 0]))
                pos += 1
            results.append(Result(req.uid, np.asarray(out_tokens),
                                  time.time() - t0))
        return results


# The request's NFE *budget* is honored by sizing the grid as
# max(1, nfe // solver_stages(name)) instead of burning n_steps * stages
# evals (a Request(nfe=10, solver="rho_rk4") used to cost 40 evals). pndm
# spends 3 extra evals on each of its 3 warmup steps, so its grid is nfe - 9
# intervals (floored at the 4 steps PNDM requires).
_PNDM_WARMUP_EXTRA = 9


@dataclasses.dataclass
class _Row:
    """Per-request bookkeeping inside a (possibly ragged) group."""
    req: Request
    n_steps: int                # TRUE solver steps of this request's own plan
    nfe: int                    # TRUE network evals (plan.nfe, pre-padding)
    deadline: float             # absolute deadline (inf when best-effort)
    done: bool = False          # Result already emitted


@dataclasses.dataclass
class _Group:
    """One in-flight stacked solve (requests admitted together).

    ``rows`` shrinks under compaction; ``k`` keeps counting from admission
    (row completion is ``k == row.n_steps`` regardless of compaction).
    """
    rows: list                  # list[_Row], aligned with the stacked axis
    sig: tuple                  # member plans' (padded, unstacked) signature
    plan: SolverPlan            # stacked: leading request axis on all leaves
    state: SAMPLER.SamplerState
    fn: Callable                # AOT-compiled step(params, plan, k, state)
    n_steps: int                # max live row n_steps (event horizon)
    compile_s: float            # 0.0 when the executor cache was warm
    priority: int               # max member Request.priority
    deadline: float             # min member absolute deadline (inf if none)
    arrival: int                # admission sequence number (tie-break)
    k: int = 0                  # steps completed
    solve_s: float = 0.0        # accumulated solve wall-time (excl. compile)
    skipped: int = 0            # consecutive ticks not selected (aging)

    @property
    def uids(self) -> tuple:
        return tuple(r.req.uid for r in self.rows)


class DiffusionServeEngine:
    """Streaming continuous-batching DEIS sampling service.

    See the module docstring for the admission / scheduling / compile-cache /
    callback contracts. ``serve`` drains a request list to completion;
    ``submit`` + ``tick`` expose the scheduler directly so callers (and
    tests) can admit requests while other groups are mid-solve.
    """

    def __init__(self, params, cfg: ModelConfig, sde: Optional[SDE] = None,
                 schedule: str = "quadratic", max_group: int = 8,
                 steps_per_tick: int | None = None, aging_ticks: int = 8,
                 compaction: bool = True):
        """``steps_per_tick``: groups advanced per tick (None = all active,
        the PR-2 behavior; an int enables true EDF selection).
        ``aging_ticks``: skipped ticks per +1 effective-priority boost
        (starvation aging). ``compaction``: retire finished rows mid-flight
        and re-pack survivors into a smaller cached batch bucket."""
        assert cfg.objective == "diffusion"
        self.params, self.cfg = params, cfg
        self.sde = sde or VPSDE()
        self.schedule = schedule
        self.max_group = max_group
        # clamp: 0/negative would make tick() select nothing and busy-loop
        self.steps_per_tick = None if steps_per_tick is None \
            else max(1, steps_per_tick)
        self.aging_ticks = max(1, aging_ticks)
        self.compaction = compaction
        self._plans: dict = {}      # (solver, nfe, eta) -> SolverPlan
        self._compiled: dict = {}   # (plan.signature, batch, seq_len) -> AOT step
        self._pending: deque = deque()   # (Request, SolverPlan, t_submit)
        self._active: list[_Group] = []
        self._arrivals = 0          # admission sequence counter
        self.ticks = 0              # scheduler ticks executed (metric)
        self.wasted_row_steps = 0   # steps burned on already-finished rows

    # ------------------------------------------------------------- plans
    def _plan(self, solver: str, nfe: int, eta: float | None) -> SolverPlan:
        if solver == "ddim_eta" and eta is None:
            raise ValueError("Request(solver='ddim_eta') requires an explicit "
                             "eta= (eta=0 deterministic, eta=1 ancestral)")
        key_ = (solver, nfe, eta)
        if key_ not in self._plans:
            if solver.lower() == "pndm":
                n_grid = max(4, nfe - _PNDM_WARMUP_EXTRA)
            else:
                n_grid = max(1, nfe // solver_stages(solver))
            ts = get_timesteps(self.sde, n_grid, self.schedule)
            kw = {"eta": eta} if solver == "ddim_eta" else {}
            self._plans[key_] = make_plan(solver, self.sde, ts, **kw)
        return self._plans[key_]

    # --------------------------------------------------------- executors
    def _executor(self, sig, plan: SolverPlan, state) -> tuple[Callable, float]:
        """AOT-compiled single step for this (signature, batch, seq_len).

        ``k`` is a traced argument, so ONE trace serves every step index of
        every group with this cache key; compiling ahead of time (instead of
        on first call) is what lets compile cost be measured apart from
        solve time."""
        key_ = (sig, state.x.shape[0], state.x.shape[1])
        if key_ in self._compiled:
            return self._compiled[key_], 0.0
        cfg = self.cfg

        def run(params, plan_arg, k, st):
            return SAMPLER.step(plan_arg, k, st, DLM.make_eps_fn(params, cfg))

        t0 = time.perf_counter()
        compiled = jax.jit(run).lower(self.params, plan, jnp.int32(0),
                                      state).compile()
        compile_s = time.perf_counter() - t0
        self._compiled[key_] = compiled
        return compiled, compile_s

    # -------------------------------------------------------- scheduling
    def submit(self, request: Request) -> None:
        """Validate and enqueue; the request is admitted into a group at the
        next tick. Validation (unknown solver, ddim_eta without eta) raises
        HERE, before the request enters the queue, so a bad request can never
        strand already-queued work mid-admission. The submit timestamp
        anchors the request's absolute deadline (``deadline_s`` is relative
        to NOW, not to admission)."""
        if request.seq_len < 1:
            raise ValueError(f"Request.seq_len must be >= 1, got "
                             f"{request.seq_len}")
        if request.nfe < 1:
            raise ValueError(f"Request.nfe must be >= 1, got {request.nfe}")
        plan = self._plan(request.solver, request.nfe,
                          request.eta if request.solver == "ddim_eta" else None)
        self._pending.append((request, plan, time.monotonic()))

    @staticmethod
    def _abs_deadline(req: Request, t_submit: float) -> float:
        return math.inf if req.deadline_s is None else t_submit + req.deadline_s

    def _admit(self) -> None:
        """Form new groups from everything pending (step-boundary admission).

        Bucketing is by (plan.family, seq_len): any mix of solver names AND
        NFE budgets whose plans pad+stack is one solve (ragged groups).
        Within a bucket the most urgent requests (priority desc, deadline
        asc) are chunked first; buckets larger than ``max_group`` split into
        multiple groups."""
        if not self._pending:
            return
        buckets: dict = {}
        while self._pending:
            r, plan, t_sub = self._pending.popleft()
            buckets.setdefault((plan.family, r.seq_len),
                               []).append((r, plan, t_sub))
        for (_fam, seq_len), items in buckets.items():
            items.sort(key=lambda it: (-it[0].priority,
                                       self._abs_deadline(it[0], it[2])))
            for i in range(0, len(items), self.max_group):
                chunk = items[i:i + self.max_group]
                n_max = max(p.n_steps for _, p, _ in chunk)
                padded = [pad_plan(p, n_max) for _, p, _ in chunk]
                sig = padded[0].signature
                plan = stack_plans(padded)
                reqs = [r for r, _, _ in chunk]
                rows = [_Row(req=r, n_steps=p.n_steps, nfe=p.nfe,
                             deadline=self._abs_deadline(r, t))
                        for (r, p, t) in chunk]
                keys = DLM.request_keys([r.seed for r in reqs])
                state = DLM.init_sample_state(
                    self.cfg, plan, keys, seq_len=seq_len,
                    prior_std=self.sde.prior_std())
                fn, compile_s = self._executor(sig, plan, state)
                self._arrivals += 1
                self._active.append(_Group(
                    rows=rows, sig=sig, plan=plan, state=state, fn=fn,
                    n_steps=n_max, compile_s=compile_s,
                    priority=max(r.priority for r in reqs),
                    deadline=min(r.deadline for r in rows),
                    arrival=self._arrivals))

    def _select(self) -> tuple[list[_Group], list[_Group]]:
        """Order active groups by urgency; return (stepped, skipped).

        Urgency key: effective priority desc (priority + skipped //
        aging_ticks, so any group skipped long enough eventually outranks
        everything at a fixed priority -- no starvation), then earliest
        absolute deadline, then admission order. ``steps_per_tick=None``
        steps every group (ordering = dispatch order only)."""
        order = sorted(
            self._active,
            key=lambda g: (-(g.priority + g.skipped // self.aging_ticks),
                           g.deadline, g.arrival))
        if self.steps_per_tick is None:
            return order, []
        return order[:self.steps_per_tick], order[self.steps_per_tick:]

    def _compact(self, g: _Group, live: list[int]) -> None:
        """Re-pack surviving rows into a smaller (sig, batch, seq_len) bucket.

        Gathers plan rows and state rows whole (coefficients, iterate, eps
        history, per-request key chains), so the surviving requests' samples
        are bit-identical to an uncompacted solve; only the executor changes,
        to the cached one for the smaller batch (compiled on first need,
        charged to this group's ``compile_s``). Group urgency is recomputed
        from the SURVIVORS so a retired urgent row's priority/deadline does
        not keep preempting other groups on behalf of best-effort leftovers."""
        g.plan = take_rows(g.plan, live)
        g.state = SAMPLER.take_state_rows(g.state, live)
        g.rows = [g.rows[i] for i in live]
        g.n_steps = max(r.n_steps for r in g.rows)
        g.priority = max(r.req.priority for r in g.rows)
        g.deadline = min(r.deadline for r in g.rows)
        g.fn, compile_s = self._executor(g.sig, g.plan, g.state)
        g.compile_s += compile_s

    @property
    def busy(self) -> bool:
        """True while any request is pending admission or mid-solve."""
        return bool(self._pending or self._active)

    def reset(self) -> None:
        """Abort all pending and in-flight work (queues cleared; the plan and
        executor caches survive -- they are pure and reusable). This is the
        recovery point after a failed tick leaves group state unreliable:
        the driver calls it before failing the affected requests' futures."""
        self._pending.clear()
        self._active.clear()

    @property
    def num_executors(self) -> int:
        """Compiled executors alive -- one per (plan.signature, batch,
        seq_len); growth during steady-state traffic means recompilation."""
        return len(self._compiled)

    def tick(self, *, on_step=None, stream_decode: bool = False) -> list[Result]:
        """One scheduler tick: admit pending requests, advance the selected
        groups one solver step each, emit Results for rows that finished.

        All selected group steps are dispatched before any is blocked on, so
        on async backends the device overlaps them; each group's ``solve_s``
        is the elapsed time from its dispatch to its step being ready (what a
        client of that group observes). A row's Result is emitted from the
        tick its OWN step count completes -- in a ragged group that is before
        the group drains -- with ``latency_s`` = the group's solve time so
        far and the row's true ``nfe``. Groups with only finished rows are
        retired; with ``compaction`` on, partially-finished groups shrink to
        their survivors."""
        self._admit()
        self.ticks += 1
        finished: list[Result] = []
        stepped, skipped = self._select()
        for g in skipped:
            g.skipped += 1
        dispatched = []
        for g in stepped:
            g.skipped = 0
            self.wasted_row_steps += sum(r.done for r in g.rows)
            t0 = time.perf_counter()
            g.state = g.fn(self.params, g.plan, jnp.int32(g.k), g.state)
            dispatched.append((g, t0))
        for g, t0 in dispatched:
            jax.block_until_ready(g.state.x)
            g.solve_s += time.perf_counter() - t0
            g.k += 1
            newly = [i for i, r in enumerate(g.rows)
                     if not r.done and r.n_steps == g.k]
            stream_toks = None
            if on_step is not None and stream_decode:
                stream_toks = np.asarray(DLM.decode_tokens(
                    self.params, self.cfg, g.state.x))
            if on_step is not None:
                on_step(StepEvent(uids=g.uids, k=g.k, n_steps=g.n_steps,
                                  tokens=stream_toks,
                                  row_steps=tuple(r.n_steps for r in g.rows)))
            if newly:
                # decode ONLY the finished rows unless a full partial decode
                # already exists (ragged groups would otherwise pay one
                # full-batch decode per distinct member NFE)
                new_toks = stream_toks[newly] if stream_toks is not None \
                    else np.asarray(DLM.decode_tokens(
                        self.params, self.cfg,
                        g.state.x[jnp.asarray(newly)]))
                for j, i in enumerate(newly):
                    g.rows[i].done = True
                    finished.append(Result(g.rows[i].req.uid, new_toks[j],
                                           g.solve_s, nfe=g.rows[i].nfe,
                                           compile_s=g.compile_s))
            live = [i for i, r in enumerate(g.rows) if not r.done]
            if not live:
                self._active.remove(g)
            elif self.compaction and len(live) < len(g.rows):
                self._compact(g, live)
        return finished

    def serve(self, requests: list[Request], *, on_step=None,
              stream_decode: bool = False) -> list[Result]:
        """Submit ``requests`` and run the scheduler until all solves finish.

        More requests may be ``submit()``-ed (e.g. from ``on_step``) while
        this drains; they are admitted at the next step boundary.

        Validation is all-or-nothing for this call: if any request is
        invalid, none of this call's requests stay queued."""
        n0 = len(self._pending)
        try:
            for r in requests:
                self.submit(r)
        except Exception:
            while len(self._pending) > n0:
                self._pending.pop()
            raise
        results: list[Result] = []
        while self.busy:
            results += self.tick(on_step=on_step, stream_decode=stream_decode)
        return results
