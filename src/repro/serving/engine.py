"""Batched serving engines.

ARServeEngine      : classic prefill + KV-cache decode loop over a request
                     queue (continuous slot-based batching).
DiffusionServeEngine: the paper's workload as a *streaming continuous-batching*
                     service over the pure ``step()`` executor.

Diffusion serving semantics
---------------------------

Admission.  ``submit()`` enqueues; at every scheduler ``tick()`` pending
requests are admitted into *groups* at a step boundary. A group stacks up to
``max_group`` requests whose plans share one :attr:`SolverPlan.signature` and
whose ``seq_len`` matches -- solver *names* may differ (ddim / euler /
naive_ei at one NFE stack into a single solve via
:func:`repro.core.plan.stack_plans`). Each request gets its own PRNG key
derived from its own ``Request.seed``, so samples are per-request
reproducible regardless of batch composition or admission time. Requests
never join a group mid-solve; they form a new group that is interleaved with
the groups already in flight.

Scheduling.  A tick advances every active group by ONE solver step
(round-robin at NFE granularity), so a newly admitted 5-NFE request starts
making progress immediately instead of waiting behind a 50-NFE group.
Finished groups are rounded to tokens and their ``Result``s emitted from the
same tick.

Compile cache.  One jitted ``step`` is AOT-compiled per
``(plan.signature, batch, seq_len)`` and reused across groups, solver names
and step indices (``k`` is a traced argument; pndm's warmup/tail split is a
``lax.cond``). ``Result.compile_s`` carries the trace+compile cost charged to
the first group that needed the executor; ``Result.latency_s`` is pure solve
wall-time, so benchmark numbers are not poisoned by trace cost.

Callback contract.  ``serve(..., on_step=fn)`` invokes ``fn(StepEvent)``
after every group step with the group's uids and progress; with
``stream_decode=True`` the event also carries the partial decode of the
current iterate (streamed tokens). The callback runs on the scheduler thread
between steps -- keep it cheap or copy the event out.

Each NFE is one full-sequence backbone forward, so this is where DEIS's
small-NFE advantage becomes throughput: serving capacity scales ~1/NFE.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import get_timesteps, make_plan
from ..core import sampler as SAMPLER
from ..core.plan import SolverPlan, solver_stages, stack_plans
from ..core.sde import SDE, VPSDE
from ..diffusion import lm as DLM
from ..models import transformer as T
from ..training.steps import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray | None = None       # AR: token prompt
    max_new_tokens: int = 32
    seq_len: int = 64                      # diffusion: sample length
    nfe: int = 10
    solver: str = "tab3"
    eta: float | None = None               # required iff solver == "ddim_eta"
    seed: int = 0


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray
    latency_s: float            # solve wall-time of the request's group,
                                # EXCLUDING compile/trace (see compile_s)
    nfe: int = 0                # true network evals spent (plan.nfe)
    compile_s: float = 0.0      # trace+compile charged to this group's
                                # executor; 0.0 on a warm compile cache


@dataclasses.dataclass
class StepEvent:
    """Per-step progress emitted to the ``on_step`` serving callback."""
    uids: tuple                      # requests in the group that just stepped
    k: int                           # steps completed (1-based after the step)
    n_steps: int                     # total solver steps for this group
    tokens: Optional[np.ndarray] = None  # (R, seq_len) partial decode when
                                         # serve(stream_decode=True)


class ARServeEngine:
    """Slot-based continuous batching: up to ``max_batch`` concurrent decodes."""

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 512):
        self.params, self.cfg = params, cfg
        self.max_batch, self.max_len = max_batch, max_len
        self._decode = jax.jit(make_decode_step(cfg))
        self._prefill = jax.jit(make_prefill_step(cfg))

    def serve(self, requests: list[Request], extras_fn=None) -> list[Result]:
        """Run all requests to completion; returns Results (greedy decode)."""
        cfg = self.cfg
        results: list[Result] = []
        queue = list(requests)
        # static single-sequence path batched over slots sequentially -- a
        # deliberately simple, correct reference loop (throughput benchmarks
        # jit the batched decode path directly).
        for req in queue:
            t0 = time.time()
            extras = extras_fn(req) if extras_fn else {}
            prompt = jnp.asarray(req.prompt)[None]
            batch = {"tokens": prompt, **extras}
            logits, cache = self._prefill(self.params, batch)
            # grow cache to max_len
            def grow(leaf):
                if leaf.ndim >= 3 and leaf.shape[2] == prompt.shape[1] and not (
                        cfg.sliding_window and leaf.shape[2] == cfg.sliding_window):
                    pad = [(0, 0)] * leaf.ndim
                    pad[2] = (0, self.max_len - leaf.shape[2])
                    return jnp.pad(leaf, pad)
                return leaf
            cache = dict(cache)
            cache["blocks"] = jax.tree.map(grow, cache["blocks"])
            tok = jnp.argmax(logits, -1)[:, None]
            out_tokens = [int(tok[0, 0])]
            pos = prompt.shape[1]
            for _ in range(req.max_new_tokens - 1):
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.int32(pos))
                tok = jnp.argmax(logits, -1)[:, None]
                out_tokens.append(int(tok[0, 0]))
                pos += 1
            results.append(Result(req.uid, np.asarray(out_tokens),
                                  time.time() - t0))
        return results


# The request's NFE *budget* is honored by sizing the grid as
# max(1, nfe // solver_stages(name)) instead of burning n_steps * stages
# evals (a Request(nfe=10, solver="rho_rk4") used to cost 40 evals). pndm
# spends 3 extra evals on each of its 3 warmup steps, so its grid is nfe - 9
# intervals (floored at the 4 steps PNDM requires).
_PNDM_WARMUP_EXTRA = 9


@dataclasses.dataclass
class _Group:
    """One in-flight stacked solve (requests admitted together)."""
    reqs: list
    plan: SolverPlan            # stacked: leading request axis on all leaves
    state: SAMPLER.SamplerState
    fn: Callable                # AOT-compiled step(params, plan, k, state)
    n_steps: int
    compile_s: float            # 0.0 when the executor cache was warm
    k: int = 0                  # steps completed
    solve_s: float = 0.0        # accumulated solve wall-time (excl. compile)


class DiffusionServeEngine:
    """Streaming continuous-batching DEIS sampling service.

    See the module docstring for the admission / scheduling / compile-cache /
    callback contracts. ``serve`` drains a request list to completion;
    ``submit`` + ``tick`` expose the scheduler directly so callers (and
    tests) can admit requests while other groups are mid-solve.
    """

    def __init__(self, params, cfg: ModelConfig, sde: Optional[SDE] = None,
                 schedule: str = "quadratic", max_group: int = 8):
        assert cfg.objective == "diffusion"
        self.params, self.cfg = params, cfg
        self.sde = sde or VPSDE()
        self.schedule = schedule
        self.max_group = max_group
        self._plans: dict = {}      # (solver, nfe, eta) -> SolverPlan
        self._compiled: dict = {}   # (plan.signature, batch, seq_len) -> AOT step
        self._pending: deque = deque()   # (Request, SolverPlan) awaiting admission
        self._active: list[_Group] = []

    # ------------------------------------------------------------- plans
    def _plan(self, solver: str, nfe: int, eta: float | None) -> SolverPlan:
        if solver == "ddim_eta" and eta is None:
            raise ValueError("Request(solver='ddim_eta') requires an explicit "
                             "eta= (eta=0 deterministic, eta=1 ancestral)")
        key_ = (solver, nfe, eta)
        if key_ not in self._plans:
            if solver.lower() == "pndm":
                n_grid = max(4, nfe - _PNDM_WARMUP_EXTRA)
            else:
                n_grid = max(1, nfe // solver_stages(solver))
            ts = get_timesteps(self.sde, n_grid, self.schedule)
            kw = {"eta": eta} if solver == "ddim_eta" else {}
            self._plans[key_] = make_plan(solver, self.sde, ts, **kw)
        return self._plans[key_]

    # --------------------------------------------------------- executors
    def _executor(self, sig, plan: SolverPlan, state) -> tuple[Callable, float]:
        """AOT-compiled single step for this (signature, batch, seq_len).

        ``k`` is a traced argument, so ONE trace serves every step index of
        every group with this cache key; compiling ahead of time (instead of
        on first call) is what lets compile cost be measured apart from
        solve time."""
        key_ = (sig, state.x.shape[0], state.x.shape[1])
        if key_ in self._compiled:
            return self._compiled[key_], 0.0
        cfg = self.cfg

        def run(params, plan_arg, k, st):
            return SAMPLER.step(plan_arg, k, st, DLM.make_eps_fn(params, cfg))

        t0 = time.perf_counter()
        compiled = jax.jit(run).lower(self.params, plan, jnp.int32(0),
                                      state).compile()
        compile_s = time.perf_counter() - t0
        self._compiled[key_] = compiled
        return compiled, compile_s

    # -------------------------------------------------------- scheduling
    def submit(self, request: Request) -> None:
        """Validate and enqueue; the request is admitted into a group at the
        next tick. Validation (unknown solver, ddim_eta without eta) raises
        HERE, before the request enters the queue, so a bad request can never
        strand already-queued work mid-admission."""
        plan = self._plan(request.solver, request.nfe,
                          request.eta if request.solver == "ddim_eta" else None)
        self._pending.append((request, plan))

    def _admit(self) -> None:
        """Form new groups from everything pending (step-boundary admission).

        Bucketing is by (plan signature, seq_len): any mix of solver names
        whose plans stack is one solve. Buckets larger than ``max_group``
        split into multiple groups."""
        if not self._pending:
            return
        buckets: dict = {}
        while self._pending:
            r, plan = self._pending.popleft()
            buckets.setdefault((plan.signature, r.seq_len),
                               []).append((r, plan))
        for (sig, seq_len), items in buckets.items():
            for i in range(0, len(items), self.max_group):
                chunk = items[i:i + self.max_group]
                reqs = [r for r, _ in chunk]
                plan = stack_plans([p for _, p in chunk])
                keys = DLM.request_keys([r.seed for r in reqs])
                state = DLM.init_sample_state(
                    self.cfg, plan, keys, seq_len=seq_len,
                    prior_std=self.sde.prior_std())
                fn, compile_s = self._executor(sig, plan, state)
                self._active.append(_Group(
                    reqs=reqs, plan=plan, state=state, fn=fn,
                    n_steps=plan.n_steps, compile_s=compile_s))

    @property
    def busy(self) -> bool:
        """True while any request is pending admission or mid-solve."""
        return bool(self._pending or self._active)

    @property
    def num_executors(self) -> int:
        """Compiled executors alive -- one per (plan.signature, batch,
        seq_len); growth during steady-state traffic means recompilation."""
        return len(self._compiled)

    def tick(self, *, on_step=None, stream_decode: bool = False) -> list[Result]:
        """One scheduler tick: admit pending requests, advance every active
        group one solver step, emit Results for groups that finished.

        All group steps are dispatched before any is blocked on, so on async
        backends the device overlaps them; each group's ``solve_s`` is the
        elapsed time from its dispatch to its step being ready (what a client
        of that group observes)."""
        self._admit()
        finished: list[Result] = []
        dispatched = []
        for g in list(self._active):
            t0 = time.perf_counter()
            g.state = g.fn(self.params, g.plan, jnp.int32(g.k), g.state)
            dispatched.append((g, t0))
        for g, t0 in dispatched:
            jax.block_until_ready(g.state.x)
            g.solve_s += time.perf_counter() - t0
            g.k += 1
            if on_step is not None:
                toks = None
                if stream_decode:
                    toks = np.asarray(DLM.decode_tokens(self.params, self.cfg,
                                                        g.state.x))
                on_step(StepEvent(uids=tuple(r.uid for r in g.reqs), k=g.k,
                                  n_steps=g.n_steps, tokens=toks))
            if g.k >= g.n_steps:
                self._active.remove(g)
                toks = np.asarray(DLM.decode_tokens(self.params, self.cfg,
                                                    g.state.x))
                for i, r in enumerate(g.reqs):
                    finished.append(Result(r.uid, toks[i], g.solve_s,
                                           nfe=g.plan.nfe,
                                           compile_s=g.compile_s))
        return finished

    def serve(self, requests: list[Request], *, on_step=None,
              stream_decode: bool = False) -> list[Result]:
        """Submit ``requests`` and run the scheduler until all solves finish.

        More requests may be ``submit()``-ed (e.g. from ``on_step``) while
        this drains; they are admitted at the next step boundary.

        Validation is all-or-nothing for this call: if any request is
        invalid, none of this call's requests stay queued."""
        n0 = len(self._pending)
        try:
            for r in requests:
                self.submit(r)
        except Exception:
            while len(self._pending) > n0:
                self._pending.pop()
            raise
        results: list[Result] = []
        while self.busy:
            results += self.tick(on_step=on_step, stream_decode=stream_decode)
        return results
