"""Batched serving engines.

ARServeEngine      : classic prefill + KV-cache decode loop over a request
                     queue (continuous slot-based batching).
DiffusionServeEngine: the paper's workload as a *streaming continuous-batching*
                     service over the pure ``step()`` executor.

Diffusion serving semantics
---------------------------

Admission.  ``submit()`` enqueues; at every scheduler ``tick()`` pending
requests are admitted into *groups* at a step boundary. A group stacks up to
``max_group`` requests whose plans share one :attr:`SolverPlan.family` and
whose (bucketed) ``seq_len`` matches -- solver *names* may differ (ddim /
euler / naive_ei stack into a single solve via
:func:`repro.core.plan.stack_plans`) and so may NFE budgets: shorter plans
are padded to the bucket's longest grid with
:func:`repro.core.plan.pad_plan` (*ragged* groups). Each request gets its
own PRNG key derived from its own ``Request.seed``, so samples are
per-request reproducible regardless of batch composition, admission time,
joining, or compaction.

Admission is *continuous*: at every compaction boundary (a tick after rows
retired, or a group carrying structural filler slots) pending same-bucket
requests may **join** the surviving in-flight group instead of waiting for
a fresh one -- joiner plan rows are padded to the group's grid and spliced
(:func:`repro.core.plan.join_rows` / ``join_state_rows``), and the executor
steps every row at its OWN count (a per-row ``k`` vector: joiners start at
0 while veterans continue), so a warm ragged workload converges to a small
fixed set of ``(family, batch, seq_len)`` executors that never drain and
never recompile. A joiner whose grid exceeds the group's horizon forms a
fresh group instead (extending the grid would change the signature).
``seq_len_buckets=(...)`` additionally rounds request lengths up to bucket
edges (the solve carries the tail as extra latent positions; every emitted
decode is masked back to the request's true ``seq_len``), so e.g. seq 48
and 64 share one executor cache entry.

Scheduling.  A tick selects up to ``steps_per_tick`` groups (default: all)
and advances each by ONE solver step, so a newly admitted 5-NFE request
starts making progress immediately instead of waiting behind a 50-NFE group.
Selection is priority/deadline-aware, not round-robin: groups are ordered by
effective priority (max member ``Request.priority``, boosted by one level
per ``aging_ticks`` consecutive skipped ticks -- starvation aging), then
earliest absolute deadline (min member ``submit time + deadline_s``; no
deadline sorts last), then admission order. With the default
``steps_per_tick=None`` every active group steps each tick and the ordering
only decides dispatch order; a throttled driver (``steps_per_tick=k``) gets
true earliest-deadline-first with guaranteed progress for starved work.

Completion, compaction & refill.  Rows of a ragged group finish at their
OWN step count (``g.k - k0 == n_steps``; a joiner's ``k0`` is its admission
tick): a finished row's Result is emitted from that very tick (its latency
is the group's solve time accumulated since ITS admission), not when the
whole group drains. With ``compaction=True`` (default) the group rebuilds
at the next tick's admission boundary, before it steps again: freed rows
are refilled with pending joiners, or the survivors are row-gathered
(:func:`repro.core.plan.take_rows` +
:func:`repro.core.sampler.take_state_rows`) into a smaller
``(signature, batch, seq_len)`` bucket and keep stepping there, instead of
burning evals on retired rows. Both moves preserve bitwise per-request
reproducibility because every per-row quantity -- coefficients, iterate,
eps history, PRNG key chain -- moves whole. ``wasted_row_steps`` counts the
steps executed on already-finished rows (zero under compaction -- joined
slots and structural filler excluded; the no-compaction baseline pays one
per dead row per tick).

Compile cache.  One jitted ``step`` is AOT-compiled per
``(plan.signature, batch, seq_len)`` and reused across groups, solver names
and step indices (``k`` is traced as a PER-ROW vector, so the same
executable serves uniform groups and post-join groups whose rows run at
their own counts; pndm's warmup/tail split is a ``lax.cond``). Compaction looks its smaller batch up in the same cache, so a
steady-state workload (e.g. the warm half of ``benchmarks/deis_serving``)
runs with ZERO recompilation. ``Result.compile_s`` carries the trace+compile
cost charged to the group that needed the executor; ``Result.latency_s`` is
pure solve wall-time, so benchmark numbers are not poisoned by trace cost.

Callback contract.  ``serve(..., on_step=fn)`` invokes ``fn(StepEvent)``
after every group step with the group's uids and progress; with
``stream_decode=True`` the event also carries the partial decode of the
current iterate (streamed tokens). ``StepEvent.row_steps`` gives each
request's own total step count so per-request progress is well-defined in a
ragged group. The callback runs on the scheduler thread between steps --
keep it cheap or copy the event out (the async ``ServeDriver`` fans it out
to per-request streams).

Each NFE is one full-sequence backbone forward, so this is where DEIS's
small-NFE advantage becomes throughput: serving capacity scales ~1/NFE.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import cached_make_plan, get_timesteps
from ..core import sampler as SAMPLER
from ..core.adaptive import RetirePolicy
from ..core.plan import (SolverPlan, inert_row, join_rows, pad_plan,
                         solver_stages, stack_plans, take_rows)
from ..core.sde import SDE, VPSDE
from ..diffusion import lm as DLM
from ..models import transformer as T
from ..obs.metrics import MetricsRegistry
from ..obs.trace import Tracer
from ..training.steps import make_decode_step, make_prefill_step


class DeadlineExceeded(RuntimeError):
    """A request's absolute deadline passed before its solve finished.

    With ``enforce_deadlines=True`` the engine evicts the row at the next
    boundary pass and emits a :class:`Result` flagged
    ``deadline_exceeded=True`` (empty tokens, true queue wait, the solve
    time spent so far). The driver converts that flag into THIS exception
    on the request's own stream -- the scheduler thread never raises it, so
    a deadline storm can degrade individual requests but never the service.
    """


class Cancelled(RuntimeError):
    """A request was cancelled (``engine.cancel`` / ``driver.cancel``).

    The engine retires the row through the same boundary machinery as a
    deadline eviction (the freed slot is recycled via join/compaction) and
    emits a :class:`Result` flagged ``cancelled=True`` (empty tokens, the
    solve time burned so far). The driver converts that flag into THIS
    exception on the request's own stream.
    """


@dataclasses.dataclass
class Request:
    """One serving request (AR or diffusion; diffusion fields listed last).

    ``priority`` (higher = more urgent) and ``deadline_s`` (latency budget in
    seconds, relative to submit time; ``None`` = best-effort) feed the
    engine's priority/deadline-aware scheduler. They influence WHEN a
    request is stepped, never WHAT it computes: samples depend only on
    ``(solver, nfe, eta, seed, seq_len)``.
    """
    uid: int
    prompt: np.ndarray | None = None       # AR: token prompt
    max_new_tokens: int = 32
    seq_len: int = 64                      # diffusion: sample length
    nfe: int = 10
    solver: str = "tab3"
    eta: float | None = None               # required iff solver == "ddim_eta"
    seed: int = 0
    priority: int = 0                      # scheduling weight (higher first)
    deadline_s: float | None = None        # latency budget from submit time


@dataclasses.dataclass
class Result:
    """Final per-request outcome. ``latency_s`` is the request's group solve
    time accumulated from ITS OWN admission tick (a joiner is not charged
    the group's pre-join solve time) up to the tick its row finished (ragged
    rows finish early); ``nfe`` is the true evals its own plan spent (never
    the padded group's); ``compile_s`` is trace+compile charged to its
    group; ``queue_wait_s`` is the time the request spent pending before
    entering a group (fresh admission or join)."""
    uid: int
    tokens: np.ndarray
    latency_s: float            # solve wall-time of the request's group
                                # since ITS admission, EXCLUDING
                                # compile/trace (see compile_s)
    nfe: int = 0                # true network evals spent (plan.nfe)
    compile_s: float = 0.0      # trace+compile charged to this group's
                                # executor; 0.0 on a warm compile cache
    queue_wait_s: float = 0.0   # submit -> admission (join or fresh group)
    deadline_exceeded: bool = False  # evicted by deadline enforcement:
                                     # tokens is empty, nfe is 0 (no sample
                                     # was produced), latency_s is the solve
                                     # time burned before eviction
    cancelled: bool = False     # retired by cancel(): tokens empty, nfe 0
    early_exit: bool = False    # retired early by the engine's RetirePolicy:
                                # tokens IS a converged sample; nfe is the
                                # evals actually spent (< the request's
                                # budget; the difference is the saved NFEs)
    final_err: float | None = None  # last local-error estimate of the row
                                    # (None when its plan carries no
                                    # embedded pair or no estimate exists)


@dataclasses.dataclass
class StepEvent:
    """Per-step progress emitted to the ``on_step`` serving callback.

    In a ragged group ``n_steps`` is the group's drain horizon (the longest
    live ``admission step + own step count``); ``row_steps[i]`` is request
    ``uids[i]``'s own total and ``row_k[i]`` its own completed count (a
    joiner's count starts at its admission tick, not group birth), so
    per-request progress is ``min(row_k[i], row_steps[i]) / row_steps[i]``
    (this is what the driver reports on each request's stream).
    """
    uids: tuple                      # requests in the group that just stepped
    k: int                           # group steps completed (1-based after
                                     # the step; joiners admit at k > 0)
    n_steps: int                     # total group steps to drain
    tokens: Optional[np.ndarray] = None  # (R, seq_len) partial decode when
                                         # serve(stream_decode=True); rows at
                                         # the group's BUCKETED seq_len
    row_steps: Optional[tuple] = None    # per-request true step counts
                                         # (aligned with uids)
    row_k: Optional[tuple] = None        # per-request completed step counts
                                         # (aligned with uids)
    row_seq_lens: Optional[tuple] = None  # per-request TRUE seq_lens (for
                                          # slicing bucketed decodes)
    row_err: Optional[tuple] = None  # per-request local-error estimates
                                     # (aligned with uids; None unless the
                                     # group's plans carry embedded pairs --
                                     # entries are +inf until a row's first
                                     # genuine estimate)


class ARServeEngine:
    """Slot-based continuous batching: up to ``max_batch`` concurrent decodes."""

    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 512):
        self.params, self.cfg = params, cfg
        self.max_batch, self.max_len = max_batch, max_len
        self._decode = jax.jit(make_decode_step(cfg))
        self._prefill = jax.jit(make_prefill_step(cfg))

    def serve(self, requests: list[Request], extras_fn=None) -> list[Result]:
        """Run all requests to completion; returns Results (greedy decode)."""
        cfg = self.cfg
        results: list[Result] = []
        queue = list(requests)
        # static single-sequence path batched over slots sequentially -- a
        # deliberately simple, correct reference loop (throughput benchmarks
        # jit the batched decode path directly).
        for req in queue:
            # perf_counter, NOT time.time(): the diffusion engine times with
            # the monotonic perf_counter, and mixing clock domains lets a
            # wall-clock step (NTP, suspend) yield negative/garbage latency.
            t0 = time.perf_counter()
            extras = extras_fn(req) if extras_fn else {}
            prompt = jnp.asarray(req.prompt)[None]
            batch = {"tokens": prompt, **extras}
            logits, cache = self._prefill(self.params, batch)
            # grow cache to max_len
            def grow(leaf):
                if leaf.ndim >= 3 and leaf.shape[2] == prompt.shape[1] and not (
                        cfg.sliding_window and leaf.shape[2] == cfg.sliding_window):
                    pad = [(0, 0)] * leaf.ndim
                    pad[2] = (0, self.max_len - leaf.shape[2])
                    return jnp.pad(leaf, pad)
                return leaf
            cache = dict(cache)
            cache["blocks"] = jax.tree.map(grow, cache["blocks"])
            tok = jnp.argmax(logits, -1)[:, None]
            out_tokens = [int(tok[0, 0])]
            pos = prompt.shape[1]
            for _ in range(req.max_new_tokens - 1):
                logits, cache = self._decode(self.params, cache, tok,
                                             jnp.int32(pos))
                tok = jnp.argmax(logits, -1)[:, None]
                out_tokens.append(int(tok[0, 0]))
                pos += 1
            results.append(Result(req.uid, np.asarray(out_tokens),
                                  time.perf_counter() - t0))
        return results


# err histogram edges: local-error estimates are small dimensionless
# magnitudes (x-space Linf), nothing like the registry's latency defaults
_ERR_EDGES = (1e-8, 1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0)


def _spent_nfe(method: str, row: "_Row", k_own: int) -> int:
    """Network evals a row has actually spent after ``k_own`` of its OWN
    steps (early exit charges what was used, not the budget). Mirrors the
    grid sizing above: rk pays its stage count per step, pndm pays 3 extra
    evals on each of its 3 warmup steps, everything else is 1:1."""
    if method == "rk":
        return k_own * max(1, row.nfe // max(1, row.n_steps))
    if method == "pndm":
        return k_own + 3 * min(k_own, 3)
    return k_own


# The request's NFE *budget* is honored by sizing the grid as
# max(1, nfe // solver_stages(name)) instead of burning n_steps * stages
# evals (a Request(nfe=10, solver="rho_rk4") used to cost 40 evals). pndm
# spends 3 extra evals on each of its 3 warmup steps, so its grid is nfe - 9
# intervals (floored at the 4 steps PNDM requires).
_PNDM_WARMUP_EXTRA = 9


@dataclasses.dataclass
class _Pending:
    """A submitted request waiting for admission (fresh group or join)."""
    req: Request
    plan: SolverPlan            # unstacked, at the request's own grid
    t_sub: float                # perf_counter at submit (deadline anchor)
    s_len: int                  # BUCKETED seq_len the solve runs at


@dataclasses.dataclass
class _Row:
    """Per-request bookkeeping inside a (possibly ragged) group.

    ``pad`` rows are structural filler, not requests: sharded admission
    rounds group sizes up to a multiple of the mesh's data-axis size with
    inert rows (``req is None``), and sharded compaction/joining may retain
    a retired request's row as filler (``req`` kept, ``pad`` flipped). Pad
    rows never emit Results, never appear in StepEvents, and never count as
    wasted steps -- they exist so the stacked axis always places evenly.

    ``k0`` is the group step count at this row's admission: a joiner starts
    solving at group step ``k0`` and its own step count is ``g.k - k0`` --
    completion, progress, NFE and latency accounting all run on that own
    count, never on the group's age.
    """
    req: Request | None
    n_steps: int                # TRUE solver steps of this request's own plan
    nfe: int                    # TRUE network evals (plan.nfe, pre-padding)
    deadline: float             # absolute deadline (inf when best-effort)
    done: bool = False          # Result already emitted
    pad: bool = False           # structural filler row (see class docstring)
    k0: int = 0                 # group step count at this row's admission
    solve_s0: float = 0.0       # group solve_s at this row's admission
    wait_s: float = 0.0         # submit -> admission queue wait


@dataclasses.dataclass
class _Group:
    """One in-flight stacked solve (requests admitted together or joined).

    ``rows`` shrinks under compaction and refills under joining; ``k``
    keeps counting from group birth (row completion is
    ``g.k - row.k0 == row.n_steps``).
    """
    rows: list                  # list[_Row], aligned with the stacked axis
    sig: tuple                  # member plans' (padded, unstacked) signature
    bucket: tuple               # admission bucket key (plan.family, s_len)
    seq_len: int                # bucketed seq_len the stacked solve runs at
    plan: SolverPlan            # stacked: leading request axis on all leaves
    state: SAMPLER.SamplerState
    fn: Callable                # AOT-compiled step(params, plan, k, state)
    n_steps: int                # max live row k0 + n_steps (drain horizon)
    compile_s: float            # 0.0 when the executor cache was warm
    priority: int               # max member Request.priority
    deadline: float             # min member absolute deadline (inf if none)
    arrival: int                # admission sequence number (tie-break)
    k: int = 0                  # steps completed
    solve_s: float = 0.0        # accumulated solve wall-time (excl. compile)
    skipped: int = 0            # consecutive ticks not selected (aging)

    @property
    def real_idx(self) -> list:
        """Stacked-axis indices of real (non-filler) rows."""
        return [i for i, r in enumerate(self.rows) if not r.pad]

    @property
    def uids(self) -> tuple:
        return tuple(self.rows[i].req.uid for i in self.real_idx)


class DiffusionServeEngine:
    """Streaming continuous-batching DEIS sampling service.

    See the module docstring for the admission / scheduling / compile-cache /
    callback contracts. ``serve`` drains a request list to completion;
    ``submit`` + ``tick`` expose the scheduler directly so callers (and
    tests) can admit requests while other groups are mid-solve.
    """

    def __init__(self, params, cfg: ModelConfig, sde: Optional[SDE] = None,
                 schedule: str = "quadratic", max_group: int = 8,
                 steps_per_tick: int | None = None, aging_ticks: int = 8,
                 compaction: bool = True, join: bool = True,
                 seq_len_buckets=None, mesh=None,
                 enforce_deadlines: bool = False,
                 retire: RetirePolicy | None = None,
                 metrics: MetricsRegistry | None = None,
                 tracer: Tracer | None = None,
                 fused: bool | None = None):
        """``steps_per_tick``: groups advanced per tick (None = all active,
        the PR-2 behavior; an int enables true EDF selection).
        ``aging_ticks``: skipped ticks per +1 effective-priority boost
        (starvation aging). ``compaction``: retire finished rows mid-flight
        and re-pack survivors into a smaller cached batch bucket.

        ``join``: continuous admission -- at every compaction boundary,
        pending same-bucket requests are spliced into the surviving group
        (retired rows become slots) instead of forming a fresh group, under
        the same priority/EDF ordering as admission. Requires ``compaction``
        (boundaries are where groups rebuild); with ``compaction=False``
        the flag is inert.

        ``seq_len_buckets``: ascending edge lengths; a request's seq_len
        rounds UP to the first edge that fits, the solve runs at the bucket
        length (tail positions ride as extra latent positions and are
        masked out of every emitted decode), and requests longer than the
        last edge run at their exact length. Bucketing trades a little
        compute on tail positions for executor reuse: seq 48 and 64 under
        a 64 edge share one (signature, batch, 64) compile-cache entry.
        Row content is bucket-independent for deterministic solvers: the
        prior is drawn at the request's TRUE length (zero-padded to the
        bucket) and a per-row ``lens`` vector masks padded tail keys out
        of every attention call, so the valid positions never see the
        tail. (Stochastic per-step solve noise is still drawn at bucket
        shape, and MoE capacity is still shared with tail tokens -- those
        rows keep a bucket-shape dependence.)

        ``mesh``: a ``jax.sharding.Mesh`` with a data-like axis (e.g.
        :func:`repro.launch.mesh.make_request_mesh`) shards every stacked
        solve over the REQUEST axis: params replicate, state/plan request
        leaves get ``NamedSharding`` placements, executors jit with explicit
        in/out shardings, and admission rounds group sizes up to a multiple
        of the data-axis size with inert filler rows so groups always place
        evenly. Sharding changes WHERE rows compute, never what: samples
        stay bitwise identical to the single-device path.

        ``enforce_deadlines``: deadlines stop being advisory. At every
        boundary pass, pending requests AND mid-flight rows whose absolute
        deadline (``submit time + deadline_s``) has passed are evicted: a
        :class:`Result` flagged ``deadline_exceeded=True`` (empty tokens)
        is emitted on the request's own stream, the freed row is recycled
        through the existing join/compaction path, and the eviction is
        counted in ``serve_deadline_evicted_total``. Off by default --
        deadlines then only order the queue (the pre-enforcement behavior),
        so latency-budget hints can never change what a request returns.

        ``retire``: a :class:`~repro.core.adaptive.RetirePolicy` enables
        adaptive early exit. Every plan is built with
        ``error_estimate=True`` (families with an embedded lower-order pair
        maintain a per-row local-error estimate in ``SamplerState.err`` at
        zero extra NFE; the rest never retire early), and the boundary pass
        retires converged rows -- estimate within the policy's tolerance
        after at least ``min_k`` own steps -- through the SAME ``take_rows``
        path as deadline eviction, emitting a Result flagged
        ``early_exit=True`` with the evals actually spent. The decision is a
        pure per-row function of the row's own (estimate, step count,
        magnitude), so the bitwise-reproducibility invariant holds in
        controller form: a solo solve under the IDENTICAL policy retires at
        the identical step with the identical sample. Under load, saved
        NFEs are throughput -- a row finishing at k=7 instead of 10 frees a
        slot a joiner fills the same boundary.

        ``metrics``: a :class:`~repro.obs.metrics.MetricsRegistry` to
        register the engine's counters/gauges/histograms in (share one per
        process to aggregate engines); ``None`` creates a private registry
        at ``engine.metrics``. ``tracer``: a
        :class:`~repro.obs.trace.Tracer` for host-side span timing of
        ticks/steps/compiles/boundary work; ``None`` builds one over the
        same registry. Instrumentation is host-side only -- nothing here
        syncs the device or touches the jitted step."""
        """``fused``: route every ``ab``-method plan through the fused
        Pallas megakernel step (psi/C combination + noise add + error-pair
        estimate in ONE kernel -- one HBM round-trip instead of r+3).
        ``None`` (default) enables it whenever the kernel is importable.
        Off only changes WHICH executor computes a step, never row content
        across group compositions: stacked fused rows are bitwise identical
        to solo fused rows (the row-block grid axis computes each row's
        blocks independently)."""
        assert cfg.objective == "diffusion"
        self.params, self.cfg = params, cfg
        self.sde = sde or VPSDE()
        self.schedule = schedule
        self.fused = (getattr(SAMPLER, "_fused_ab_step", None) is not None) \
            if fused is None else bool(fused)
        self.max_group = max_group
        # clamp: 0/negative would make tick() select nothing and busy-loop
        self.steps_per_tick = None if steps_per_tick is None \
            else max(1, steps_per_tick)
        self.aging_ticks = max(1, aging_ticks)
        self.compaction = compaction
        self.join = join
        if seq_len_buckets is not None:
            edges = tuple(int(e) for e in seq_len_buckets)
            if not edges or any(e < 1 for e in edges) or \
                    list(edges) != sorted(set(edges)):
                raise ValueError("seq_len_buckets must be strictly ascending "
                                 f"positive edges, got {seq_len_buckets!r}")
            seq_len_buckets = edges
        self.seq_len_buckets = seq_len_buckets
        self.mesh = mesh
        if mesh is not None:
            from ..launch.mesh import mesh_fingerprint
            from ..sharding.rules import batch_axes
            self._mesh_key = mesh_fingerprint(mesh)
            self._data_size = int(np.prod(
                [mesh.shape[a] for a in batch_axes(mesh)])) or 1
            if self._data_size > self.max_group:
                raise ValueError(
                    f"mesh data-axis size {self._data_size} exceeds "
                    f"max_group={self.max_group}: every group must round up "
                    "to a multiple of the axis, so the smallest placeable "
                    "group would already break the max_group bound. Raise "
                    "max_group or shrink the mesh.")
            # quantize the chunk size so rounded-up groups NEVER exceed the
            # operator's max_group bound (e.g. max_group=10 on an 8-way axis
            # admits 8-request chunks, not 10 -> 16)
            self._chunk_cap = (self.max_group // self._data_size) \
                * self._data_size
            # replicate params over the mesh ONCE; executors AND decode take
            # them as-placed so no per-call transfer happens, and the
            # engine's own reference is the replicated copy (keeping the
            # caller's single-device original alive too would double param
            # memory on device 0)
            self._params_exec = jax.device_put(
                params, jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()))
            self.params = self._params_exec
        else:
            self._mesh_key = None
            self._data_size = 1
            self._chunk_cap = self.max_group
            self._params_exec = params
        self._plans: dict = {}      # (solver, nfe, eta) -> SolverPlan
        self._compiled: dict = {}   # (signature, batch, seq_len, mesh_key)
                                    #   -> AOT step
        self._pending: deque = deque()   # deque[_Pending]
        self._active: list[_Group] = []
        self._arrivals = 0          # admission sequence counter
        self.enforce_deadlines = enforce_deadlines
        self.retire = retire
        # Results produced OUTSIDE a group step (deadline evictions,
        # cancellations, early exits) -- drained into the next tick's
        # finished list
        self._boundary_results: list[Result] = []

        # ---- observability: every scheduler metric lives in the registry;
        # the legacy int counters (ticks/wasted_row_steps/joined_requests)
        # are back-compat properties over it. Metric objects are resolved
        # ONCE here -- the tick loop touches attributes, never the registry.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(self.metrics)
        reg = self.metrics
        self._m_ticks = reg.counter(
            "serve_ticks_total", "scheduler ticks executed")
        self._m_wasted = reg.counter(
            "serve_wasted_row_steps_total",
            "steps burned on already-finished request rows")
        self._m_joined = reg.counter(
            "serve_joined_requests_total",
            "requests admitted by joining an in-flight group")
        self._m_submitted = reg.counter(
            "serve_submitted_total", "requests accepted by submit()")
        self._m_completed = reg.counter(
            "serve_completed_total", "requests finished with a sample")
        self._m_evicted = reg.counter(
            "serve_deadline_evicted_total",
            "requests evicted by deadline enforcement")
        self._m_compactions = reg.counter(
            "serve_compactions_total", "mid-flight group compactions")
        self._m_cache_hits = reg.counter(
            "serve_compile_cache_hits_total",
            "executor lookups served by the AOT compile cache")
        self._m_cache_misses = reg.counter(
            "serve_compile_cache_misses_total",
            "executor lookups that traced+compiled a new executable")
        self._m_compile_s = reg.counter(
            "serve_compile_seconds_total",
            "cumulative AOT trace+compile wall time")
        self._g_queue = reg.gauge(
            "serve_queue_depth", "requests pending admission")
        self._g_groups = reg.gauge(
            "serve_active_groups", "stacked groups in flight")
        self._g_occupancy = reg.gauge(
            "serve_group_occupancy",
            "live request rows / stacked row slots across active groups")
        self._m_cancelled = reg.counter(
            "serve_cancelled_total", "requests retired by cancel()")
        self._m_early = reg.counter(
            "serve_early_exit_total",
            "requests retired early by the RetirePolicy (converged rows)")
        self._m_saved_nfe = reg.counter(
            "serve_saved_nfe_total",
            "network evals saved by early exit (budgeted minus spent)")
        self._h_queue_wait = reg.histogram(
            "serve_queue_wait_seconds", "submit -> admission (join or fresh)")
        self._h_row_err = reg.histogram(
            "serve_row_err", "local-error estimate at row retirement",
            edges=_ERR_EDGES)
        self._h_solve = reg.histogram(
            "serve_solve_seconds",
            "per-request group solve time since its own admission")
        self._h_step = reg.histogram(
            "serve_step_seconds", "one group step, dispatch to ready")
        self._h_tick = reg.histogram(
            "serve_tick_seconds", "one full scheduler tick")

    # ---- legacy int counters: back-compat views over the registry. The
    # setters exist because benchmarks/tests re-zero them between the cold
    # (compile) pass and the warm measured pass.
    @property
    def ticks(self) -> int:
        """Scheduler ticks executed (metric)."""
        return int(self._m_ticks.value)

    @ticks.setter
    def ticks(self, v: int) -> None:
        self._m_ticks.reset(v)

    @property
    def wasted_row_steps(self) -> int:
        """Steps burned on already-finished rows (metric)."""
        return int(self._m_wasted.value)

    @wasted_row_steps.setter
    def wasted_row_steps(self, v: int) -> None:
        self._m_wasted.reset(v)

    @property
    def joined_requests(self) -> int:
        """Requests admitted by joining an in-flight group (metric)."""
        return int(self._m_joined.value)

    @joined_requests.setter
    def joined_requests(self, v: int) -> None:
        self._m_joined.reset(v)

    # ------------------------------------------------------------- plans
    def _plan(self, solver: str, nfe: int, eta: float | None) -> SolverPlan:
        if solver == "ddim_eta" and eta is None:
            raise ValueError("Request(solver='ddim_eta') requires an explicit "
                             "eta= (eta=0 deterministic, eta=1 ancestral)")
        key_ = (solver, nfe, eta)
        if key_ not in self._plans:
            if solver.lower() == "pndm":
                n_grid = max(4, nfe - _PNDM_WARMUP_EXTRA)
            else:
                n_grid = max(1, nfe // solver_stages(solver))
            ts = get_timesteps(self.sde, n_grid, self.schedule)
            kw = {"eta": eta} if solver == "ddim_eta" else {}
            if self.retire is not None:
                # uniform request across mixed traffic: families without an
                # embedded pair ignore it (their flag stays False)
                kw["error_estimate"] = True
            # coefficient construction is memoized process-wide (keyed on
            # family + schedule fingerprint + grid + kwargs), so admission
            # of a known (solver, nfe, eta) never re-runs the float64
            # host precompute
            plan = cached_make_plan(solver, self.sde, ts, **kw)
            if self.fused and plan.method == "ab":
                plan = dataclasses.replace(plan, fused=True)
            self._plans[key_] = plan
        return self._plans[key_]

    # --------------------------------------------------------- executors
    def _shardings(self, plan: SolverPlan, state):
        """(plan, state) NamedSharding trees for this engine's mesh (or
        (None, None) unsharded). NamedShardings are shape-agnostic, so the
        same trees place any batch size whose request axis divides the data
        axes -- which admission's group-size rounding guarantees."""
        if self.mesh is None:
            return None, None
        return SAMPLER._request_shardings(plan, state, self.mesh)

    def _executor(self, sig, plan: SolverPlan, state) -> tuple[Callable, float]:
        """AOT-compiled single step for this (signature, batch, seq_len,
        mesh).

        ``k`` is a traced argument, so ONE trace serves every step index of
        every group with this cache key; compiling ahead of time (instead of
        on first call) is what lets compile cost be measured apart from
        solve time. Under a mesh the executor is jitted with explicit
        in/out shardings (params replicated, request-axis leaves over the
        data axes), and the mesh fingerprint keys the cache so a mesh swap
        can never silently reuse a stale placement."""
        key_ = (sig, state.x.shape[0], state.x.shape[1], self._mesh_key)
        if key_ in self._compiled:
            self._m_cache_hits.inc()
            return self._compiled[key_], 0.0
        self._m_cache_misses.inc()
        cfg = self.cfg

        def run(params, plan_arg, k, st, lens):
            return SAMPLER.step(plan_arg, k, st,
                                DLM.make_eps_fn(params, cfg, valid_len=lens))

        # k is lowered as a PER-ROW (R,) step vector: one trace serves both
        # groups admitted whole (all entries equal -- bitwise identical to a
        # scalar k) and post-join groups whose rows run at their own counts.
        # lens is the PER-ROW (R,) true-length vector: bucketed rows mask
        # their padded tail keys out of attention, so sample content is
        # independent of the bucket the row landed in (full-length rows pass
        # lens == seq_len, an all-true mask).
        k0 = jnp.zeros((state.x.shape[0],), jnp.int32)
        lens0 = jnp.full((state.x.shape[0],), state.x.shape[1], jnp.int32)
        t0 = time.perf_counter()
        if self.mesh is None:
            jitted = jax.jit(run)
        else:
            from ..sharding.rules import step_index_specs, to_shardings
            plan_sh, state_sh = self._shardings(plan, state)
            param_sh = jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec())
            k_sh = to_shardings(step_index_specs(k0, self.mesh), self.mesh)
            lens_sh = to_shardings(step_index_specs(lens0, self.mesh),
                                   self.mesh)
            jitted = jax.jit(run, in_shardings=(param_sh, plan_sh, k_sh,
                                                state_sh, lens_sh),
                             out_shardings=state_sh)
        with self.tracer.span("compile"):
            compiled = jitted.lower(self._params_exec, plan, k0,
                                    state, lens0).compile()
        compile_s = time.perf_counter() - t0
        self._m_compile_s.inc(compile_s)
        self._compiled[key_] = compiled
        return compiled, compile_s

    # -------------------------------------------------------- scheduling
    def _bucket_len(self, seq_len: int) -> int:
        """Bucketed solve length: the first edge >= seq_len, or the exact
        length when no edge fits (or bucketing is off)."""
        if self.seq_len_buckets is not None:
            for edge in self.seq_len_buckets:
                if seq_len <= edge:
                    return edge
        return seq_len

    def submit(self, request: Request) -> None:
        """Validate and enqueue; the request is admitted at the next tick --
        into a fresh group, or spliced into an in-flight one at a compaction
        boundary. Validation (unknown solver, ddim_eta without eta) raises
        HERE, before the request enters the queue, so a bad request can never
        strand already-queued work mid-admission. The submit timestamp
        anchors the request's absolute deadline (``deadline_s`` is relative
        to NOW, not to admission)."""
        if request.seq_len < 1:
            raise ValueError(f"Request.seq_len must be >= 1, got "
                             f"{request.seq_len}")
        if request.nfe < 1:
            raise ValueError(f"Request.nfe must be >= 1, got {request.nfe}")
        plan = self._plan(request.solver, request.nfe,
                          request.eta if request.solver == "ddim_eta" else None)
        # perf_counter everywhere: one monotonic clock domain for deadlines,
        # solve timing and compile timing (mixing in wall-clock time.time()
        # was the old LM-loop bug -- negative latencies across a clock step).
        self._pending.append(_Pending(request, plan, time.perf_counter(),
                                      self._bucket_len(request.seq_len)))
        self._m_submitted.inc()
        self._g_queue.set(len(self._pending))

    @staticmethod
    def _abs_deadline(req: Request, t_submit: float) -> float:
        return math.inf if req.deadline_s is None else t_submit + req.deadline_s

    def _group_key(self, g: _Group) -> tuple:
        """Urgency ordering shared by ``_select`` and the join/compact
        boundary pass: effective priority desc (starvation aging), earliest
        absolute deadline, admission order."""
        return (-(g.priority + g.skipped // self.aging_ticks),
                g.deadline, g.arrival)

    def _evict_expired(self, now: float) -> None:
        """Deadline enforcement (``enforce_deadlines=True``): shed pending
        requests and retire mid-flight rows whose absolute deadline has
        passed. Evicted rows are marked ``done`` so the ordinary boundary
        pass recycles their slots (join refill / ``take_rows`` compaction /
        structural filler) exactly like normally-retired rows; a group left
        with no live rows is dropped whole. Each eviction emits a
        ``deadline_exceeded`` Result (drained by this tick) and increments
        ``serve_deadline_evicted_total``. Never raises: a deadline storm
        degrades the affected requests only."""
        empty = np.zeros(0, np.int32)
        still = deque()
        while self._pending:
            p = self._pending.popleft()
            if self._abs_deadline(p.req, p.t_sub) < now:
                self._m_evicted.inc()
                self._h_queue_wait.observe(now - p.t_sub)
                self._boundary_results.append(Result(
                    p.req.uid, empty, 0.0, nfe=0,
                    queue_wait_s=now - p.t_sub, deadline_exceeded=True))
            else:
                still.append(p)
        self._pending = still
        for g in list(self._active):
            for r in g.rows:
                if r.done or r.pad or not (r.deadline < now):
                    continue
                r.done = True
                self._m_evicted.inc()
                self._h_queue_wait.observe(r.wait_s)
                self._boundary_results.append(Result(
                    r.req.uid, empty, g.solve_s - r.solve_s0, nfe=0,
                    compile_s=g.compile_s, queue_wait_s=r.wait_s,
                    deadline_exceeded=True))
            if not any(not r.done for r in g.rows):
                self._active.remove(g)

    def cancel(self, uid: int) -> bool:
        """Cancel request ``uid``: drop it from the pending queue, or retire
        its mid-flight row through the deadline-eviction machinery (the slot
        recycles via join/compaction at the next boundary). Emits a Result
        flagged ``cancelled=True`` (drained by the next tick; ``busy`` stays
        True until then so a driver loop always delivers it). Returns False
        when ``uid`` is unknown -- already finished, already evicted, or
        never submitted -- and cancellation is a no-op (the original Result
        stands). Runs on the scheduler thread (the driver routes cancels
        through its inbox)."""
        empty = np.zeros(0, np.int32)
        now = time.perf_counter()
        for p in list(self._pending):
            if p.req.uid == uid:
                self._pending.remove(p)
                self._g_queue.set(len(self._pending))
                self._m_cancelled.inc()
                self._h_queue_wait.observe(now - p.t_sub)
                self._boundary_results.append(Result(
                    uid, empty, 0.0, nfe=0, queue_wait_s=now - p.t_sub,
                    cancelled=True))
                return True
        for g in list(self._active):
            for r in g.rows:
                if r.pad or r.done or r.req.uid != uid:
                    continue
                r.done = True
                self._m_cancelled.inc()
                self._h_queue_wait.observe(r.wait_s)
                self._boundary_results.append(Result(
                    uid, empty, g.solve_s - r.solve_s0, nfe=0,
                    compile_s=g.compile_s, queue_wait_s=r.wait_s,
                    cancelled=True))
                if not any(not row.done for row in g.rows):
                    self._active.remove(g)
                return True
        return False

    def _retire_converged(self) -> None:
        """Early-exit pass (``retire`` policy set): retire rows whose local
        error estimate has converged, BEFORE the boundary pass rebuilds
        groups -- a freed slot is a join slot the very same tick.

        A row is eligible once it has taken ``min_k`` of its OWN steps and
        before its natural horizon; convergence is the policy's pure per-row
        decision over ``(err, |x|_inf)`` -- rows whose plans carry no
        embedded pair report err=+inf and never pass. Retired rows emit a
        full Result (their iterate IS the converged sample, decoded and
        masked to the true seq_len) flagged ``early_exit=True`` with
        ``nfe`` = evals actually spent; the saved difference feeds
        ``serve_saved_nfe_total``. Groups whose plans carry no estimates are
        skipped without touching the device."""
        pol = self.retire
        for g in list(self._active):
            if not g.plan.error_estimate:
                continue
            cand = [i for i, r in enumerate(g.rows)
                    if not r.done and not r.pad
                    and pol.min_k <= g.k - r.k0 < r.n_steps]
            if not cand:
                continue
            # repro: allow[RL001] early-exit boundary: err fetch gates retirement
            err = np.asarray(jax.device_get(g.state.err), np.float64)
            if pol.norm == "rel":
                x = g.state.x
                # repro: allow[RL001] boundary fetch, amortized over the whole group
                x_inf = np.asarray(jnp.max(
                    jnp.abs(x), axis=tuple(range(1, x.ndim))), np.float64)
            else:
                x_inf = np.zeros(len(g.rows))
            mask = pol.converged(err[cand], x_inf[cand])
            hit = [i for i, m in zip(cand, mask) if m]
            if not hit:
                continue
            # repro: allow[RL001] retiring rows leave the device here by design
            toks = np.asarray(DLM.decode_tokens(
                self._params_exec, self.cfg, g.state.x[jnp.asarray(hit)]))
            for j, i in enumerate(hit):
                r = g.rows[i]
                r.done = True
                k_own = g.k - r.k0
                spent = _spent_nfe(g.plan.method, r, k_own)
                self._m_completed.inc()
                self._m_early.inc()
                self._m_saved_nfe.inc(max(0, r.nfe - spent))
                self._h_row_err.observe(float(err[i]))
                self._h_queue_wait.observe(r.wait_s)
                lat = g.solve_s - r.solve_s0
                self._h_solve.observe(lat)
                self._boundary_results.append(Result(
                    r.req.uid, toks[j][:r.req.seq_len], lat, nfe=spent,
                    compile_s=g.compile_s, queue_wait_s=r.wait_s,
                    early_exit=True, final_err=float(err[i])))
            if not any(not r.done for r in g.rows):
                self._active.remove(g)

    def _admit(self) -> None:
        """Admit everything pending (step-boundary admission).

        Two phases, both ordered by the same urgency key (priority desc,
        deadline asc):

        1. *Boundary pass* (``compaction`` on): every group carrying
           retired/filler rows rebuilds before its next step -- pending
           same-bucket requests whose grids fit the group's horizon JOIN it
           (retired rows become slots; ``join`` on), and what cannot be
           refilled compacts down to its survivors. Groups are visited in
           ``_select``'s urgency order, so the most urgent in-flight work
           gets the most urgent joiners.
        2. *Fresh groups*: remaining pending requests bucket by
           ``(plan.family, bucketed seq_len)`` -- any mix of solver names
           AND NFE budgets whose plans pad+stack is one solve (ragged
           groups) -- and chunk at ``max_group``.

        Under a mesh, each chunk/join target is rounded UP to a multiple of
        the data-axis size with inert filler rows
        (:func:`repro.core.plan.inert_row`): the stacked axis then always
        divides the mesh's data axes, so every group places evenly and the
        executor cache sees only multiple-of-axis batch sizes. Chunking is
        quantized to ``(max_group // axis) * axis`` so rounding can never
        exceed the operator's ``max_group`` bound. Filler rows are born
        ``done`` -- they emit nothing, cost no extra wall-clock in a
        data-parallel step, and are first in line to become join slots.

        With ``enforce_deadlines`` an *eviction pass* runs first: pending
        requests already past their absolute deadline are shed without ever
        forming a group, and mid-flight rows past theirs are marked done
        with a ``deadline_exceeded`` Result -- the ordinary boundary pass
        below then recycles their slots through the SAME ``take_rows``
        join/compaction path every retired row goes through."""
        now = time.perf_counter()
        if self.enforce_deadlines:
            self._evict_expired(now)
        if self.retire is not None:
            self._retire_converged()
        buckets: dict = {}
        while self._pending:
            p = self._pending.popleft()
            buckets.setdefault((p.plan.family, p.s_len), []).append(p)
        self._g_queue.set(0)
        for items in buckets.values():
            items.sort(key=lambda it: (-it.req.priority,
                                       self._abs_deadline(it.req, it.t_sub)))
        if self.compaction:
            for g in sorted(self._active, key=self._group_key):
                if not any(r.done for r in g.rows):
                    continue
                cands = buckets.get(g.bucket) if self.join else None
                if cands and self._join_group(g, cands, now):
                    continue
                live = [i for i, r in enumerate(g.rows) if not r.done]
                keep = self._compact_target(g, live)
                if keep is not None:
                    self._compact(g, keep)
                else:
                    # the group already sits at the smallest placeable
                    # multiple of the data axis (mesh only: unsharded groups
                    # always shrink): its retired rows are structurally
                    # required filler -- same status as rows retained by a
                    # compaction -- not waste (and open join slots)
                    for r in g.rows:
                        if r.done:
                            r.pad = True
        for (_fam, s_len), items in buckets.items():
            for i in range(0, len(items), self._chunk_cap):
                chunk = items[i:i + self._chunk_cap]
                n_max = max(p.plan.n_steps for p in chunk)
                padded = [pad_plan(p.plan, n_max) for p in chunk]
                rows = [_Row(req=p.req, n_steps=p.plan.n_steps,
                             nfe=p.plan.nfe,
                             deadline=self._abs_deadline(p.req, p.t_sub),
                             wait_s=now - p.t_sub)
                        for p in chunk]
                seeds = [p.req.seed for p in chunk]
                n_fill = (-len(chunk)) % self._data_size
                if n_fill:
                    filler = inert_row(padded[0])
                    padded += [filler] * n_fill
                    rows += [_Row(req=None, n_steps=n_max, nfe=0,
                                  deadline=math.inf, done=True, pad=True)
                             for _ in range(n_fill)]
                    seeds += [0] * n_fill
                sig = padded[0].signature
                plan = stack_plans(padded)
                keys = DLM.request_keys(seeds)
                state = DLM.init_sample_state(
                    self.cfg, plan, keys, seq_len=s_len,
                    prior_std=self.sde.prior_std(),
                    valid_lens=[p.req.seq_len for p in chunk]
                    + [s_len] * n_fill)
                fn, compile_s = self._executor(sig, plan, state)
                plan_sh, state_sh = self._shardings(plan, state)
                if plan_sh is not None:
                    plan = jax.device_put(plan, plan_sh)
                    state = jax.device_put(state, state_sh)
                reqs = [p.req for p in chunk]
                self._arrivals += 1
                self._active.append(_Group(
                    rows=rows, sig=sig, bucket=(_fam, s_len), seq_len=s_len,
                    plan=plan, state=state, fn=fn,
                    n_steps=n_max, compile_s=compile_s,
                    priority=max(r.priority for r in reqs),
                    deadline=min(r.deadline for r in rows),
                    arrival=self._arrivals))

    def _join_group(self, g: _Group, cands: list, now: float) -> bool:
        """Splice pending requests into ``g`` at a compaction boundary.

        ``cands`` is the group's admission bucket, urgency-sorted; joiners
        are taken from the front, skipping any whose grid exceeds the
        group's horizon (they form fresh groups instead -- extending the
        grid would change the signature and recompile). The rebuilt batch
        keeps the surviving rows in their original relative order, each
        carried whole and bitwise-unmoved (``take_rows`` of the survivors,
        then ``join_rows`` appending the padded joiners), rounds up to a
        data-axis multiple reusing retired rows as slots before allocating
        inert filler, and stays within ``max_group``. Joiner
        rows record ``k0 = g.k`` (their steps count from THIS tick) and
        ``solve_s0`` (their latency excludes the group's past). Returns
        False when nothing could join (caller falls back to compaction)."""
        live = [i for i, r in enumerate(g.rows) if not r.done]
        cap = self._chunk_cap - len(live)
        if cap <= 0:
            return False
        take, rest = [], []
        for p in cands:
            if len(take) < cap and p.plan.n_steps <= g.plan.n_steps:
                take.append(p)
            else:
                rest.append(p)
        if not take:
            return False
        cands[:] = rest
        keep, n_inert = self._round_keep(g, live, len(take))
        plan_sh, state_sh = self._shardings(g.plan, g.state)
        if keep != list(range(len(g.rows))):
            # the intermediate gather may not be a data-axis multiple (e.g.
            # 8 rows -> 4 survivors before 4 joiners splice back to 8), so
            # it stays uncommitted; only the FINAL spliced batch -- always
            # a multiple -- is placed (join_rows/join_state_rows below)
            g.plan = take_rows(g.plan, keep)
            g.state = SAMPLER.take_state_rows(g.state, keep)
            g.rows = [g.rows[i] for i in keep]
        for r in g.rows:
            if r.done:          # retained retired row: structural filler
                r.pad = True
        padded = [pad_plan(p.plan, g.plan.n_steps) for p in take]
        seeds = [p.req.seed for p in take]
        new_rows = [_Row(req=p.req, n_steps=p.plan.n_steps, nfe=p.plan.nfe,
                         deadline=self._abs_deadline(p.req, p.t_sub),
                         k0=g.k, solve_s0=g.solve_s, wait_s=now - p.t_sub)
                    for p in take]
        if n_inert:
            filler = inert_row(padded[0])
            padded += [filler] * n_inert
            seeds += [0] * n_inert
            new_rows += [_Row(req=None, n_steps=0, nfe=0, deadline=math.inf,
                              done=True, pad=True, k0=g.k)
                         for _ in range(n_inert)]
        keys = DLM.request_keys(seeds)
        add_state = DLM.init_sample_state(
            self.cfg, stack_plans(padded), keys, seq_len=g.seq_len,
            prior_std=self.sde.prior_std(),
            valid_lens=[p.req.seq_len for p in take]
            + [g.seq_len] * n_inert)
        g.plan = join_rows(g.plan, padded, shardings=plan_sh)
        g.state = SAMPLER.join_state_rows(g.state, add_state,
                                          shardings=state_sh)
        g.rows += new_rows
        live_rows = [r for r in g.rows if not r.done]
        g.n_steps = max(r.k0 + r.n_steps for r in live_rows)
        g.priority = max(r.req.priority for r in live_rows)
        g.deadline = min(r.deadline for r in live_rows)
        g.fn, compile_s = self._executor(g.sig, g.plan, g.state)
        g.compile_s += compile_s
        self._m_joined.inc(len(take))
        return True

    def _select(self) -> tuple[list[_Group], list[_Group]]:
        """Order active groups by urgency; return (stepped, skipped).

        Urgency key: effective priority desc (priority + skipped //
        aging_ticks, so any group skipped long enough eventually outranks
        everything at a fixed priority -- no starvation), then earliest
        absolute deadline, then admission order. ``steps_per_tick=None``
        steps every group (ordering = dispatch order only)."""
        order = sorted(self._active, key=self._group_key)
        if self.steps_per_tick is None:
            return order, []
        return order[:self.steps_per_tick], order[self.steps_per_tick:]

    def _round_keep(self, g: _Group, live: list[int],
                    n_new: int) -> tuple[list[int], int]:
        """Rebuild arithmetic shared by compaction and joining.

        The rebuilt batch is ``len(live) + n_new`` rounded up to a
        data-axis multiple; the round-up gap is filled with already-retired
        rows kept as structural padding (original filler first, then
        retired requests, lowest index first). Returns ``(keep, n_inert)``:
        the row indices to gather (live + retained filler, original order)
        and how many fresh inert rows must still be allocated when retired
        rows alone cannot cover the gap (only possible while joining --
        compaction's target never exceeds the current batch)."""
        target = len(live) + n_new
        target += (-target) % self._data_size
        fillers = [i for i, r in enumerate(g.rows) if r.done]
        fillers.sort(key=lambda i: (not g.rows[i].pad, i))
        reuse = fillers[:max(0, target - len(live) - n_new)]
        return (sorted(live + reuse),
                target - len(live) - n_new - len(reuse))

    def _compact_target(self, g: _Group, live: list[int]) -> list[int] | None:
        """Row indices to KEEP when compacting ``g``, or None to skip.

        Unsharded: keep exactly the live rows (compact whenever any row
        retired). Under a mesh the kept count must stay a multiple of the
        data-axis size (:meth:`_round_keep`); when the rounded target
        equals the current batch there is nothing to shrink and compaction
        is skipped (no resharding, no recompile, no churn).
        """
        keep, _ = self._round_keep(g, live, 0)
        if len(keep) >= len(g.rows):
            return None
        return keep

    def _compact(self, g: _Group, keep: list[int]) -> None:
        """Re-pack kept rows into a smaller (sig, batch, seq_len) bucket.

        Gathers plan rows and state rows whole (coefficients, iterate, eps
        history, per-request key chains), so the surviving requests' samples
        are bit-identical to an uncompacted solve; only the executor changes,
        to the cached one for the smaller batch (compiled on first need,
        charged to this group's ``compile_s``). Under a mesh the gathers are
        sharding-preserving (committed straight back to the request-axis
        ``NamedSharding``), so mid-flight shrink never reshards or
        recompiles. Group urgency is recomputed from the LIVE survivors so a
        retired urgent row's priority/deadline does not keep preempting
        other groups on behalf of best-effort leftovers."""
        self._m_compactions.inc()
        plan_sh, state_sh = self._shardings(g.plan, g.state)
        g.plan = take_rows(g.plan, keep, shardings=plan_sh)
        g.state = SAMPLER.take_state_rows(g.state, keep, shardings=state_sh)
        g.rows = [g.rows[i] for i in keep]
        live = []
        for r in g.rows:
            if r.done:
                r.pad = True        # retained retired row: structural filler
            else:
                live.append(r)
        g.n_steps = max(r.k0 + r.n_steps for r in live)
        g.priority = max(r.req.priority for r in live)
        g.deadline = min(r.deadline for r in live)
        g.fn, compile_s = self._executor(g.sig, g.plan, g.state)
        g.compile_s += compile_s

    @property
    def busy(self) -> bool:
        """True while any request is pending admission or mid-solve, or a
        boundary Result (eviction/cancellation/early exit) awaits drain."""
        return bool(self._pending or self._active or self._boundary_results)

    def reset(self) -> None:
        """Abort all pending and in-flight work (queues cleared; the plan and
        executor caches survive -- they are pure and reusable). This is the
        recovery point after a failed tick leaves group state unreliable:
        the driver calls it before failing the affected requests' futures."""
        self._pending.clear()
        self._active.clear()
        self._boundary_results.clear()
        self._g_queue.set(0)
        self._g_groups.set(0)
        self._g_occupancy.set(0.0)

    @property
    def num_executors(self) -> int:
        """Compiled executors alive -- one per (plan.signature, batch,
        seq_len, mesh fingerprint); growth during steady-state traffic means
        recompilation."""
        # repro: allow[RL003] GIL-atomic len() for stats; one-tick staleness is fine
        return len(self._compiled)

    def tick(self, *, on_step=None, stream_decode: bool = False) -> list[Result]:
        """One scheduler tick: admit pending requests (joining in-flight
        groups at compaction boundaries, else forming fresh ones), advance
        the selected groups one solver step each, emit Results for rows
        that finished.

        All selected group steps are dispatched before any is blocked on, so
        on async backends the device overlaps them; each group's ``solve_s``
        is the elapsed time from its dispatch to its step being ready (what a
        client of that group observes). Every group steps with a per-row
        ``k`` vector (row ``i`` at ``g.k - k0``), so joiners and veterans
        advance on their own grids in one executor call. A row's Result is
        emitted from the tick its OWN step count completes -- in a ragged
        group that is before the group drains -- with ``latency_s`` = the
        group's solve time since the row's admission and the row's true
        ``nfe``. Groups with only finished rows are retired; groups left
        with retired rows rebuild (join or compact) at the next tick's
        admission boundary, before they step again."""
        t_tick = time.perf_counter()
        with self.tracer.span("admit"):
            self._admit()
        self._m_ticks.inc()
        finished: list[Result] = []
        if self._boundary_results:          # deadline enforcement this tick
            finished += self._boundary_results
            self._boundary_results = []
        stepped, skipped = self._select()
        for g in skipped:
            g.skipped += 1
        dispatched = []
        with self.tracer.span("dispatch"):
            for g in stepped:
                g.skipped = 0
                # structural filler rows (pad) are free capacity in a
                # data-parallel step, not waste; only retired REQUEST rows
                # that keep stepping count. With compaction on, the
                # admission-time boundary pass has already joined over /
                # compacted away / pad-marked every retired row, so this
                # stays zero.
                self._m_wasted.inc(sum(
                    r.done and not r.pad for r in g.rows))
                k_vec = jnp.asarray([g.k - r.k0 for r in g.rows], jnp.int32)
                lens_vec = jnp.asarray(
                    [r.req.seq_len if r.req is not None else g.seq_len
                     for r in g.rows], jnp.int32)
                t0 = time.perf_counter()
                g.state = g.fn(self._params_exec, g.plan, k_vec, g.state,
                               lens_vec)
                dispatched.append((g, t0))
        for g, t0 in dispatched:
            with self.tracer.span("step_wait"):
                # repro: allow[RL001] THE documented boundary sync: one wait per
                # group-step after all groups dispatched (see module docstring)
                jax.block_until_ready(g.state.x)
            dt_step = time.perf_counter() - t0
            g.solve_s += dt_step
            self._h_step.observe(dt_step)
            g.k += 1
            newly = [i for i, r in enumerate(g.rows)
                     if not r.done and r.k0 + r.n_steps == g.k]
            # decode against the as-placed params (replicated under a mesh):
            # a data-sharded iterate composes with them eagerly, so the
            # sharded and unsharded paths share one decode expression
            stream_toks = None
            if on_step is not None and stream_decode:
                # repro: allow[RL001] opt-in stream decode: caller chose per-step
                # token delivery over peak throughput
                stream_toks = np.asarray(DLM.decode_tokens(
                    self._params_exec, self.cfg, g.state.x))
            # one host pull of the per-row error estimates serves both the
            # step event and natural-finish final_err (plans without
            # embedded pairs skip the transfer entirely)
            err_v = None
            if g.plan.error_estimate and (on_step is not None or newly):
                # repro: allow[RL001] single err pull serves step event + final_err
                err_v = np.asarray(jax.device_get(g.state.err), np.float64)
            if on_step is not None:
                real = g.real_idx
                on_step(StepEvent(
                    uids=g.uids, k=g.k, n_steps=g.n_steps,
                    tokens=stream_toks[real] if stream_toks is not None
                    else None,
                    row_steps=tuple(g.rows[i].n_steps for i in real),
                    row_k=tuple(g.k - g.rows[i].k0 for i in real),
                    row_seq_lens=tuple(g.rows[i].req.seq_len for i in real),
                    row_err=tuple(float(err_v[i]) for i in real)
                    if err_v is not None else None))
            if newly:
                # decode ONLY the finished rows unless a full partial decode
                # already exists (ragged groups would otherwise pay one
                # full-batch decode per distinct member NFE)
                new_toks = (stream_toks[newly] if stream_toks is not None
                            # repro: allow[RL001] finished rows leave the device here by design
                            else np.asarray(DLM.decode_tokens(
                                self._params_exec, self.cfg,
                                g.state.x[jnp.asarray(newly)])))
                for j, i in enumerate(newly):
                    row = g.rows[i]
                    row.done = True
                    # bucketed admission: mask the solve's tail positions
                    # back to the request's true seq_len. final_err is None
                    # (not +inf) when no estimate exists: Results serialize
                    # to strict JSON, which has no Infinity literal.
                    f_err = None
                    if err_v is not None and math.isfinite(err_v[i]):
                        f_err = float(err_v[i])
                    res = Result(
                        row.req.uid, new_toks[j][:row.req.seq_len],
                        g.solve_s - row.solve_s0, nfe=row.nfe,
                        compile_s=g.compile_s, queue_wait_s=row.wait_s,
                        final_err=f_err)
                    self._m_completed.inc()
                    self._h_queue_wait.observe(res.queue_wait_s)
                    self._h_solve.observe(res.latency_s)
                    finished.append(res)
            if not any(not r.done for r in g.rows):
                self._active.remove(g)
        self._g_groups.set(len(self._active))
        slots = sum(len(g.rows) for g in self._active)
        live = sum(sum(not r.done for r in g.rows) for g in self._active)
        self._g_occupancy.set(live / slots if slots else 0.0)
        self._h_tick.observe(time.perf_counter() - t_tick)
        return finished

    def serve(self, requests: list[Request], *, on_step=None,
              stream_decode: bool = False) -> list[Result]:
        """Submit ``requests`` and run the scheduler until all solves finish.

        More requests may be ``submit()``-ed (e.g. from ``on_step``) while
        this drains; they are admitted at the next step boundary.

        Validation is all-or-nothing for this call: if any request is
        invalid, none of this call's requests stay queued."""
        n0 = len(self._pending)
        try:
            for r in requests:
                self.submit(r)
        except Exception:
            while len(self._pending) > n0:
                self._pending.pop()
            raise
        results: list[Result] = []
        while self.busy:
            results += self.tick(on_step=on_step, stream_decode=stream_decode)
        return results
