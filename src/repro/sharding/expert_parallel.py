"""Expert-parallel MoE via shard_map + all_to_all (opt-in, beyond-paper).

The baseline MoE (models/layers.moe) is tensor-parallel: every device holds a
d_ff shard of EVERY expert and tokens stay put. Expert parallelism instead
shards EXPERTS across a mesh axis and moves TOKENS with all_to_all -- the
GShard/Switch production layout. Traffic per device ~ 2 x (capacity x
d_model) each way, independent of d_ff: wins when d_ff is large relative to
d_model x top_k (grok: F=32768 vs D*k=12288).

Requirements: num_experts % axis_size == 0. Routing math (top-k, capacity,
position-in-expert) matches models/layers.moe's gather dispatch; equivalence
is tested on a real 4-device CPU mesh in tests/test_expert_parallel.py
(subprocess, so the main test process keeps seeing 1 device).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig

# jax.shard_map is the modern alias; older jax ships it under experimental
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map


def moe_expert_parallel(params, cfg: ModelConfig, x, mesh, axis: str = "data"):
    """Expert-parallel MoE.

    params: standard init_moe pytree {router (D,E), w_up/w_gate (E,D,F),
      w_down (E,F,D)}; expert weights sharded over ``axis`` on dim 0, router
      replicated.
    x: (B, S, D), batch sharded over ``axis``.
    Returns (out, aux) with out sharded like x.
    """
    mcfg = cfg.moe
    n_shards = mesh.shape[axis]
    e = mcfg.num_experts
    assert e % n_shards == 0, (e, n_shards)
    e_loc = e // n_shards
    k = mcfg.top_k

    in_specs = (
        {"router": P(), "w_up": P(axis), "w_gate": P(axis), "w_down": P(axis)},
        P(axis, None, None),
    )

    def _ep(p, x_loc):
        b, s, d = x_loc.shape
        n_tok = b * s
        xf = x_loc.reshape(n_tok, d)
        cap = max(1, int(mcfg.capacity_factor * s * k / e)) * b
        cap = min(cap, n_tok)

        logits = xf.astype(jnp.float32) @ p["router"]
        gates = jax.nn.softmax(logits, axis=-1)                  # (N, E)
        gate_vals, gate_idx = jax.lax.top_k(gates, k)            # (N, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

        choice = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (N, k, E)
        flat = choice.reshape(n_tok * k, e)
        pos = (jnp.cumsum(flat, axis=0) - flat)                  # (N*k, E)
        pos = jnp.sum(pos.reshape(n_tok, k, e) * choice, -1)     # (N, k)
        valid = pos < cap

        # local (E, cap, D) dispatch buffer
        slot = (gate_idx * cap + pos.astype(jnp.int32)).reshape(-1)
        vflat = valid.reshape(-1)
        slot = jnp.where(vflat, slot, e * cap)
        tok_ids = jnp.broadcast_to(jnp.arange(n_tok)[:, None],
                                   (n_tok, k)).reshape(-1)
        table = jnp.zeros((e * cap + 1,), jnp.int32).at[slot].set(
            jnp.where(vflat, tok_ids, 0).astype(jnp.int32))[:-1]
        occ = jnp.zeros((e * cap + 1,), jnp.bool_).at[slot].set(vflat)[:-1]
        buf = jnp.where(occ[:, None], xf[table], 0)              # (E*cap, D)
        buf = buf.reshape(n_shards, e_loc * cap, d)

        # tokens -> expert shards: recv[src] = src's slab for MY experts
        recv = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                                  tiled=False)
        recv = recv.reshape(n_shards, e_loc, cap, d).transpose(1, 0, 2, 3)
        recv = recv.reshape(e_loc, n_shards * cap, d)

        h = jnp.einsum("ecd,edf->ecf", recv, p["w_up"])
        g = jnp.einsum("ecd,edf->ecf", recv, p["w_gate"])
        h = (jax.nn.silu(g.astype(jnp.float32)) * h.astype(jnp.float32)
             ).astype(recv.dtype)
        out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

        out_e = out_e.reshape(e_loc, n_shards, cap, d).transpose(1, 0, 2, 3)
        out_e = out_e.reshape(n_shards, e_loc * cap, d)
        back = jax.lax.all_to_all(out_e, axis, split_axis=0, concat_axis=0,
                                  tiled=False)
        back = back.reshape(e * cap, d)

        gf = (gate_idx * cap + pos.astype(jnp.int32))
        gf = jnp.where(valid, gf, 0)
        got = back[gf]                                            # (N, k, D)
        w = (gate_vals * valid).astype(got.dtype)
        out = jnp.einsum("nk,nkd->nd", w, got).reshape(b, s, d)

        me = jnp.mean(gates, axis=0)
        frac = jnp.mean(jnp.sum(choice * valid[..., None], axis=1), axis=0)
        lb = e * jnp.sum(me * frac) * mcfg.load_balance_loss
        lb = jax.lax.pmean(lb, axis)
        return out, lb

    mapped = _shard_map(_ep, mesh=mesh, in_specs=in_specs,
                        out_specs=(P(axis, None, None), P()))
    out, lb = mapped(params, x)
    return out, {"moe_lb": lb}
