"""Sharding rules engine: param/cache/batch pytrees -> PartitionSpec trees.

Baseline policy (hillclimbed variants live behind flags; see EXPERIMENTS.md
§Perf):

  * batch dims  -> all data-like mesh axes ('pod','data').
  * tensor parallel over 'model': output-feature dims of up-projections
    (wq/wk/wv/w_up/w_gate/moe experts' d_ff) and input-feature dims of
    down-projections (wo/w_down/out_proj) -- Megatron pairing, so each
    block needs one all-reduce per mixer/MLP, not per matmul.
  * FSDP over 'data' on a *second* axis of large weights (opt-in per config
    size) so optimizer states fit for the 314B/398B configs.
  * every rule checks divisibility against the mesh axis size and falls back
    to replication (whisper-tiny's 6 heads simply replicate on a 16-way
    'model' axis; its d_ff=1536 still shards).

The engine is path-pattern based and validated by tests against every arch.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(n: int, mesh: Mesh, axis) -> bool:
    if isinstance(axis, tuple):
        size = int(np.prod([_axis_size(mesh, a) for a in axis]))
    else:
        size = _axis_size(mesh, axis)
    return n % size == 0


def _spec(mesh: Mesh, shape, assignments: dict[int, object]) -> P:
    """Build a PartitionSpec assigning mesh axes to dims where divisible."""
    parts: list = [None] * len(shape)
    for dim, axis in assignments.items():
        d = dim % len(shape)
        if axis is not None and _div(shape[d], mesh, axis):
            parts[d] = axis
    return P(*parts)


# matched in order; first hit wins. Patterns are regexes over the "/"-joined
# tree path (e.g. "blocks/slot0/attn/wq").
def _param_rules(fsdp: bool, ff2d: bool = False):
    """fsdp: shard a second weight axis over 'data' (ZeRO-style).

    ff2d (beyond-paper §Perf lever): for FFN/MoE weights, put the 'data'
    factor on the FEED-FORWARD dim together with 'model' instead of on the
    contraction (d_model) dim. Sharding the contraction dim makes GSPMD emit
    partial-sum all-reduces of the full (tokens x d_ff) activations (~TB/step
    for grok-scale MoE); 2D-sharding d_ff keeps activations sharded and costs
    only one (tokens x d_model) all-reduce per layer.
    """
    f = "data" if fsdp else None
    ff_up = {-1: ("data", "model") if (fsdp and ff2d) else "model",
             -2: None if ff2d else f}
    ff_down = {-2: ("data", "model") if (fsdp and ff2d) else "model",
               -1: None if ff2d else f}
    return [
        (r"embed$",            lambda sh, m: _spec(m, sh, {0: "model", 1: f})),
        (r"lm_head$",          lambda sh, m: _spec(m, sh, {1: "model", 0: f})),
        (r"eps_head$",         lambda sh, m: _spec(m, sh, {1: "model"})),
        (r"(wq|wk|wv)$",       lambda sh, m: _spec(m, sh, {-1: "model", -2: f})),
        (r"(w_up|w_gate)$",    lambda sh, m: _spec(m, sh, dict(ff_up))),
        (r"wo$",               lambda sh, m: _spec(m, sh, {-2: "model", -1: f})),
        (r"(w_down|out_proj)$", lambda sh, m: _spec(m, sh, dict(ff_down))),
        (r"in_proj$",          lambda sh, m: _spec(m, sh, {-1: "model", -2: f})),
        (r"router$",           lambda sh, m: P()),
        (r"conv_w$",           lambda sh, m: _spec(m, sh, {-1: "model"})),
        (r"conv_b$",           lambda sh, m: _spec(m, sh, {-1: "model"})),
        (r"norm",              lambda sh, m: P()),
        (r"(A_log|dt_bias|D)$", lambda sh, m: P()),
        (r"time_mlp",          lambda sh, m: P()),
        (r".*",                lambda sh, m: P()),
    ]


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def param_specs(params_shape, mesh: Mesh, fsdp: bool = False,
                ff2d: bool = False):
    """PartitionSpec tree for a params (or opt-state m/v) shape pytree."""
    rules = _param_rules(fsdp, ff2d)

    def assign(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        for pat, fn in rules:
            if re.search(pat, ps):
                return fn(shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def opt_state_specs(opt_state_shape, params_spec, mesh: Mesh):
    """OptState(step, m, v): moments shard like params; step replicated."""
    from ..training.optimizer import OptState
    return OptState(P(), params_spec, jax.tree.map(lambda s: s, params_spec))


def batch_specs(batch_shape, mesh: Mesh):
    """Input batch: leading dim over ('pod','data') when divisible."""
    ba = batch_axes(mesh)

    def assign(path, leaf):
        if leaf.ndim == 0:
            return P()
        if _div(leaf.shape[0], mesh, ba):
            return P(ba, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(assign, batch_shape)


def cache_specs(cache_shape, mesh: Mesh, seq_shard: bool = True):
    """Decode/prefill KV+SSM cache specs.

    Attention K/V (nb, B, S, KV, hd): batch over data axes; when the batch
    does not cover the data axes (long-context, batch=1) shard the SEQ dim
    over 'model' (flash-decode style -- XLA resolves the softmax reduction);
    otherwise shard kv-heads/hd over 'model' when divisible.
    SSM state (nb, B, H, P, N): shard heads over 'model' when divisible.
    """
    ba = batch_axes(mesh)

    def assign(path, leaf):
        ps = _path_str(path)
        sh = leaf.shape
        if leaf.ndim == 0:
            return P()
        parts: list = [None] * leaf.ndim
        # leading dim is the stacked-blocks axis for block caches ("blocks/"
        # or "cross/" prefixed); batch is dim 1 there, else dim 0.
        bdim = 1 if ps.startswith(("blocks", "cross")) else 0
        if bdim < leaf.ndim and _div(sh[bdim], mesh, ba):
            parts[bdim] = ba
        if re.search(r"/(k|v)$", ps) and leaf.ndim >= bdim + 4:
            seq_d, kv_d, hd_d = bdim + 1, bdim + 2, bdim + 3
            if _div(sh[kv_d], mesh, "model"):
                parts[kv_d] = "model"
            elif _div(sh[hd_d], mesh, "model"):
                parts[hd_d] = "model"
            elif seq_shard and _div(sh[seq_d], mesh, "model"):
                parts[seq_d] = "model"
        elif re.search(r"/state$", ps) and leaf.ndim >= bdim + 4:
            if _div(sh[bdim + 1], mesh, "model"):
                parts[bdim + 1] = "model"
        elif re.search(r"/conv$", ps) and leaf.ndim >= bdim + 3:
            if _div(sh[-1], mesh, "model"):
                parts[-1] = "model"
        return P(*parts)

    return jax.tree_util.tree_map_with_path(assign, cache_shape)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# -------------------------------------------- request-axis (serving) sharding
def _leading_axis_spec(leaf, mesh: Mesh, dim: int) -> P:
    """P with the data axes on ``dim`` when divisible, else replicated."""
    ba = batch_axes(mesh)
    parts: list = [None] * leaf.ndim
    if ba and dim < leaf.ndim and _div(leaf.shape[dim], mesh, ba):
        parts[dim] = ba[0] if len(ba) == 1 else ba
    return P(*parts)


def plan_specs(plan, mesh: Mesh):
    """PartitionSpec tree for a *stacked* :class:`~repro.core.plan.SolverPlan`.

    Every dynamic leaf of a stacked plan (coefficient arrays and ``ts``)
    carries the request axis leading, so each is sharded over the data-like
    mesh axes when the batch divides evenly and replicated otherwise.
    Unstacked plans (no request axis) replicate entirely. The result has the
    plan's own tree structure, so it can be passed directly as a jit
    ``in_shardings`` entry (static metadata rides in the treedef).
    """
    stacked = getattr(plan, "stacked", False)
    return jax.tree.map(
        lambda leaf: _leading_axis_spec(leaf, mesh, 0) if stacked else P(),
        plan)


def step_index_specs(k, mesh: Mesh) -> P:
    """Spec for the executor's step-index argument.

    A per-row ``(R,)`` step vector (post-join serving groups: each row runs
    at its own step count) shards over the data-like axes alongside the
    request-axis leaves it indexes, so the per-row coefficient gather stays
    local to each shard; a group-uniform scalar ``k`` replicates.
    """
    return _leading_axis_spec(k, mesh, 0) if getattr(k, "ndim", 0) else P()


def state_specs(state, mesh: Mesh):
    """PartitionSpec tree for a stacked :class:`SamplerState`.

    The request axis is sharded over the data-like mesh axes: ``x`` is
    ``(R, *inner)`` (axis 0), ``hist`` is ``(history_len, R, *inner)``
    (axis 1), the per-request key stack is ``(R, 2)`` (axis 0), the per-row
    error estimate ``err`` is ``(R,)`` (axis 0), and the step counter ``k``
    is replicated. Non-divisible (or unstacked, ``key.ndim != 2``) states
    fall back to replication leaf-wise.
    """
    from ..core.sampler import SamplerState  # local: avoid core<->sharding cycle
    stacked = state.key.ndim == 2
    return SamplerState(
        x=_leading_axis_spec(state.x, mesh, 0) if stacked else P(),
        hist=_leading_axis_spec(state.hist, mesh, 1) if stacked else P(),
        key=_leading_axis_spec(state.key, mesh, 0) if stacked else P(),
        k=P(),
        err=_leading_axis_spec(state.err, mesh, 0) if stacked else P())
