"""Checkpointing: pytree -> .npz (arrays) + .json (treedef/metadata).

No orbax offline; this is a complete, restart-safe implementation with atomic
writes and step-indexed directories.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in path) for path, _ in leaves]
    arrs = [np.asarray(leaf) for _, leaf in leaves]
    return paths, arrs, treedef


def save(ckpt_dir: str, step: int, tree, metadata: dict | None = None) -> str:
    """Atomically write a checkpoint; returns the step directory."""
    paths, arrs, _ = _flatten(tree)
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir if os.path.isdir(ckpt_dir) else None)
    os.makedirs(ckpt_dir, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": a for i, a in enumerate(arrs)})
    manifest = {
        "paths": paths,
        "dtypes": [str(a.dtype) for a in arrs],
        "shapes": [list(a.shape) for a in arrs],
        "step": step,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.isdir(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp, step_dir)
    return step_dir


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like, step: int | None = None):
    """Restore into the structure of ``like`` (a pytree of arrays or shapes)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(step_dir, "arrays.npz"))
    arrs = [data[f"a{i}"] for i in range(len(manifest["paths"]))]

    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    want_paths = ["/".join(str(p) for p in path) for path, _ in leaves]
    by_path = dict(zip(manifest["paths"], arrs))
    out_leaves = []
    for path, leaf in zip(want_paths, (l for _, l in leaves)):
        if path not in by_path:
            raise KeyError(f"checkpoint missing {path}")
        arr = by_path[path]
        dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
        out_leaves.append(jnp.asarray(arr, dtype=dtype))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), out_leaves), \
        manifest["metadata"]
