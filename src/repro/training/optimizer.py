"""Pure-JAX AdamW + LR schedules + global-norm clipping (no optax offline)."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(1, warmup)
        prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(base_lr: float):
    return lambda step: jnp.full((), base_lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr_fn: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), zeros,
                        jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: OptState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

        step = state.step + 1
        lr = self.lr_fn(step)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, OptState(step, m, v), {"grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))
