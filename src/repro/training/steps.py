"""Jittable train / serve step builders used by the launcher and the dry-run.

Semantics per assigned input shape:
  train_*   -> ``train_step``: one optimizer step on the configured objective
               ('diffusion' = paper-native eps-matching, 'ar' = causal LM).
  prefill_* -> ``prefill_step``: full-sequence forward producing logits + KV.
  decode_*  -> ``decode_step``: ONE new token against a seq_len cache.
Plus ``deis_sample_step``: one DEIS solver NFE in embedding space (the paper's
technique as a serving workload).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.sde import SDE, VPSDE
from ..diffusion import lm as DLM
from ..models import transformer as T
from .optimizer import AdamW


def cross_entropy(logits, targets, cfg: ModelConfig):
    """Token CE. cfg.ce_mode:
    'gather' -- log_softmax + take_along_axis (baseline; all-gathers
                vocab-sharded logits to resolve the gather).
    'onehot' -- logsumexp + one-hot CONTRACTION over vocab: the contraction
                dim may stay sharded (partial-sum all-reduce of (B,S) scalars
                instead of an all-gather of (B,S,V) logits)."""
    logits = logits.astype(jnp.float32)
    if cfg.ce_mode == "onehot":
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
        picked = jnp.sum(logits * onehot, axis=-1)
        return jnp.mean(lse - picked)
    logp = jax.nn.log_softmax(logits, -1)
    return -jnp.mean(jnp.take_along_axis(logp, targets[..., None], axis=-1))


def ar_loss(params, cfg: ModelConfig, tokens, *, prefix=None, frames=None,
            remat: bool = False, unroll: int = 1, block_constraint=None):
    out = T.forward(params, cfg, tokens=tokens, mode="train", causal=True,
                    prefix=prefix, frames=frames, remat=remat, unroll=unroll,
                    block_constraint=block_constraint)
    logits = out["logits"]
    if cfg.arch_type == "vlm" and prefix is not None:
        logits = logits[:, prefix.shape[1]:]
    loss = cross_entropy(logits[:, :-1], tokens[:, 1:], cfg)
    aux = sum(out["aux"].values()) if out["aux"] else 0.0
    return loss + aux, {"loss": loss, "ppl": jnp.exp(loss)}


def make_loss_fn(cfg: ModelConfig, sde: Optional[SDE] = None, remat=False,
                 unroll: int = 1, block_constraint=None):
    """remat: False | 'block' (jax.checkpoint per scan block -- production
    memory profile) | 'loss' (checkpoint the whole loss -- cheap to compile,
    used for the full-depth dry-run lowering proof)."""
    sde = sde or VPSDE()
    block_remat = remat == "block" or remat is True

    def loss_fn(params, batch, rng):
        kw = {k: batch[k] for k in ("prefix", "frames") if k in batch}
        if cfg.objective == "diffusion":
            return DLM.diffusion_loss(params, cfg, sde, batch["tokens"], rng,
                                      remat=block_remat, unroll=unroll,
                                      block_constraint=block_constraint, **kw)
        return ar_loss(params, cfg, batch["tokens"], remat=block_remat,
                       unroll=unroll, block_constraint=block_constraint, **kw)

    if remat == "loss":
        return jax.checkpoint(loss_fn)
    return loss_fn


def make_train_step(cfg: ModelConfig, opt: AdamW, sde: Optional[SDE] = None,
                    remat=False, unroll: int = 1, block_constraint=None):
    loss_fn = make_loss_fn(cfg, sde, remat=remat, unroll=unroll,
                           block_constraint=block_constraint)

    def train_step(params, opt_state, batch, rng):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, rng)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics = dict(metrics, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, unroll: int = 1):
    def prefill_step(params, batch):
        kw = {k: batch[k] for k in ("prefix", "frames") if k in batch}
        out = T.forward(params, cfg, tokens=batch["tokens"], mode="prefill",
                        causal=True, unroll=unroll, **kw)
        return out["logits"][:, -1], out["cache"]
    return prefill_step


def make_decode_step(cfg: ModelConfig, unroll: int = 1):
    def decode_step(params, cache, token, cache_index):
        out = T.forward(params, cfg, tokens=token, mode="decode", causal=True,
                        cache=cache, cache_index=cache_index, unroll=unroll)
        return out["logits"][:, -1], out["cache"]
    return decode_step


def make_deis_sample_step(cfg: ModelConfig, sde: Optional[SDE] = None,
                          unroll: int = 1):
    """One DEIS NFE: eps eval + fused multistep update (paper Eq. 14)."""
    sde = sde or VPSDE()

    def deis_step(params, x, eps_hist, t, psi_k, coeff_row):
        eps_fn = DLM.make_eps_fn(params, cfg, unroll=unroll)
        eps = eps_fn(x, t)
        hist = jnp.concatenate([eps[None], eps_hist[:-1]], axis=0)
        x_next = psi_k * x + jnp.tensordot(coeff_row, hist, axes=1)
        return x_next, hist

    return deis_step
