"""Known-bad RL001 fixture: one of every hot-path sync pattern."""
# repro: hot-path
import jax
import jax.numpy as jnp
import numpy as np


def leaky_step(plan, k, x):
    err = x.item()
    jax.block_until_ready(x)
    host = np.asarray(x)
    print("step", k)
    scale = float(jnp.max(x))
    if jnp.any(x > 0):
        x = x * scale
    while (x > 0).all():
        x = x - err
    return x, host
