"""Known-good RL001 fixture: host-side bookkeeping the taint pass must
recognize (numpy/math results, len(), coercions of already-host values)."""
# repro: hot-path
import math

import numpy as np


def plan_table(n):
    ts = np.linspace(0.0, 1.0, n)
    tab = np.asarray(ts, dtype=np.float64)
    total = float(np.sum(tab))
    if len(ts) > 3 and math.isfinite(total):
        tab = tab * 2.0
    k = int(len(ts))
    return tab, total, k
