"""Known-bad RL002 fixture: every recompile hazard the checker knows."""
import jax


def make_solvers(fns, flag, run_step):
    compiled = {}
    for i, fn in enumerate(fns):
        compiled[f"fn{i}"] = jax.jit(lambda x: fn(x) * i)
    step = jax.jit(run_step, static_argnums=flag)
    return compiled, step


def run_step(x, interpret=False):
    return x * 2


def build(x):
    step = jax.jit(run_step)
    return step(x)


def lookup(compiled, spec):
    return compiled.get(tuple(spec.items()))
