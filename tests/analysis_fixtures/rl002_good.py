"""Known-good RL002 fixture: hoisted jit, literal statics, tuple cache
keys, sorted dict iteration."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("interpret",))
def run_step(x, interpret=False):
    return x * 2


def build(fns):
    compiled = {}
    for i, fn in enumerate(fns):
        compiled[("fn", i)] = fn
    return compiled


def lookup(compiled, spec):
    return compiled.get(tuple(sorted(spec.items())))
