"""Known-bad RL003 fixture: a ServeDriver breaking its ownership table
(locked attr outside the lock, config mutated after __init__, an attr the
table does not know about)."""
import queue
import threading


class ServeDriver:
    def __init__(self, engine):
        self.engine = engine
        self.max_pending = 4
        self._lock = threading.Lock()
        self._inbox = queue.Queue()
        self._streams = {}
        self._thread = None

    def submit(self, request):
        self._streams[request.uid] = request
        self._inbox.put(request)
        self.max_pending = 8
        self._scratch = []
        return request

    def stats(self):
        return len(self._streams)
