"""Known-good RL003 fixture: the same ServeDriver honoring its table."""
import queue
import threading


class ServeDriver:
    def __init__(self, engine):
        self.engine = engine
        self.max_pending = 4
        self._lock = threading.Lock()
        self._inbox = queue.Queue()
        self._streams = {}
        self._thread = None

    def submit(self, request):
        with self._lock:
            self._streams[request.uid] = request
        self._inbox.put(request)
        return request

    def stats(self):
        with self._lock:
            return {"in_flight": len(self._streams),
                    "max_pending": self.max_pending}
