"""Known-bad RL004 fixture: overlapping registries, a stray modifier key,
a builder inventing unregistered coefficient keys, and a state_specs call
missing a SamplerState field."""
import numpy as np

_PER_STEP_COEFFS = frozenset({"ab_coeffs", "noise_scale"})
_PER_KNOT_COEFFS = frozenset({"ts", "noise_scale"})
_STATIC_COEFFS = frozenset({"tableau"})
_TIME_LIKE = frozenset({"ts", "sigma_grid"})


def _mk(name, coeffs):
    return name, coeffs


def plan_demo(n):
    coeffs = {"ab_coeffs": np.zeros((n, 3)), "mystery": np.ones(n)}
    coeffs["tableau"] = np.eye(3)
    coeffs.update(extra_gain=np.ones(n))
    return _mk("demo", coeffs)


class SamplerState:
    x: object
    hist: object
    key: object


def state_specs(mesh):
    return SamplerState(x="data", hist="data")
