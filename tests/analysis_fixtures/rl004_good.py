"""Known-good RL004 fixture: disjoint registries, a modifier that is a
subset of a primary, a builder using only registered keys, and a complete
state_specs."""
import numpy as np

_PER_STEP_COEFFS = frozenset({"ab_coeffs"})
_PER_KNOT_COEFFS = frozenset({"ts"})
_STATIC_COEFFS = frozenset({"tableau"})
_TIME_LIKE = frozenset({"ts"})


def _mk(name, coeffs):
    return name, coeffs


def plan_demo(n):
    coeffs = {"ab_coeffs": np.zeros((n, 3)), "ts": np.linspace(0.0, 1.0, n)}
    coeffs["tableau"] = np.eye(3)
    return _mk("demo", coeffs)


class SamplerState:
    x: object
    hist: object
    key: object


def state_specs(mesh):
    return SamplerState(x="data", hist="data", key=None)
