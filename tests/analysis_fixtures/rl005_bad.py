"""Known-bad RL005 fixture: jitted signatures defaulting interpret=True.

Every site marks ``interpret`` static (so RL002 stays quiet -- the cache
key is fine); the VALUE is the bug: the default ships the interpreter.
"""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_a(x, interpret=True):
    return x * 2


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_b(x, *, interpret: bool = True):
    return x * 3


def kernel_c(x, *, interpret=True):
    return x * 4


def kernel_d(x, interpret=True):
    return x * 5


def build():
    jitted_c = jax.jit(kernel_c, static_argnames=("interpret",))
    jitted_d = functools.partial(jax.jit, kernel_d,
                                 static_argnames=("interpret",))
    return jitted_c, jitted_d
