"""Known-good RL005 fixture: None-defaulted interpret resolved per kernel,
explicit False, and an un-jitted helper where a True default is harmless."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("interpret",))
def kernel_a(x, *, interpret: bool = False):
    return x * 2


def kernel_b(x, *, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _kernel_b_jit(x, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _kernel_b_jit(x, *, interpret: bool):
    return x * 3


def reference_oracle(x, interpret=True):
    # never jitted: a debugging helper may default to the interpreter
    return x * 4
