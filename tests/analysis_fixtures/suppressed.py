"""Suppression fixture: a real RL001 finding carrying a justified allow
comment -- reported as [allowed], does not fail the run."""
# repro: hot-path
import numpy as np


def boundary(x):
    # repro: allow[RL001] boundary decode: the solve is already complete here
    return np.asarray(x)
