import jax
import pytest

# float64 for numerical-analysis tests (solver orders, coefficient identities).
# Model/kernel tests explicitly cast to float32/bfloat16 where relevant.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
