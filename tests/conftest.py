import sys
import types

import jax
import pytest

# float64 for numerical-analysis tests (solver orders, coefficient identities).
# Model/kernel tests explicitly cast to float32/bfloat16 where relevant.
jax.config.update("jax_enable_x64", True)

# hypothesis is optional: on a stock environment without it, property-based
# tests skip instead of breaking collection for the whole suite.
try:
    import hypothesis  # noqa: F401
except ImportError:
    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")

    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def _settings(*_a, **_k):
        return lambda fn: fn

    def _strategy(*_a, **_k):
        return None

    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "just", "one_of"):
        setattr(_st, _name, _strategy)
    _hyp.given, _hyp.settings, _hyp.strategies = _given, _settings, _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture
def no_implicit_transfers():
    """Fail the test on any implicit device<->host transfer.

    The dynamic twin of the RL001 static lint: inside this fixture jax
    raises on implicit transfers (e.g. ``bool(x > 0)``, ``x + np_array``)
    while explicit ones (``jax.device_get``, ``jnp.asarray(np_arr)``) stay
    allowed. Build inputs and jit BEFORE requesting the guard (list this
    fixture after any prep fixtures); fetch results with ``jax.device_get``.
    """
    with jax.transfer_guard("disallow"):
        yield
