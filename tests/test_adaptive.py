"""Unit coverage for ``repro.core.adaptive``: the shared error-control
primitives (:func:`error_ratio` / :func:`step_factor`), the serving-side
:class:`RetirePolicy`, and the :class:`AdaptiveRK23` controller's
accept/reject accounting -- plus the ``SamplerState.err`` estimate semantics
both policies consume (inf-until-first-estimate, zero NFE overhead, and the
non-perturbation invariant early-exit serving rests on)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (VPSDE, get_timesteps, init_state, make_plan, step)
from repro.core.adaptive import (AdaptiveRK23, RetirePolicy, error_ratio,
                                 step_factor)


# ------------------------------------------------------------- error_ratio
def test_error_ratio_exact_value():
    y_hi = jnp.array([1.0, 2.0])
    y_lo = jnp.array([1.0, 1.5])
    y_prev = jnp.array([0.5, 1.0])
    # elementwise: |diff| / (atol + rtol*max(|y_hi|,|y_prev|)), take the max
    want = 0.5 / (0.1 + 0.1 * 2.0)
    got = error_ratio(y_hi, y_lo, y_prev, atol=0.1, rtol=0.1)
    assert got == pytest.approx(want)


def test_error_ratio_properties_seeded():
    rng = np.random.RandomState(0)
    for _ in range(25):
        y_hi = jnp.asarray(rng.randn(8))
        y_lo = jnp.asarray(rng.randn(8))
        y_prev = jnp.asarray(rng.randn(8))
        atol, rtol = 10 ** rng.uniform(-6, -1), 10 ** rng.uniform(-6, -1)
        r = error_ratio(y_hi, y_lo, y_prev, atol, rtol)
        assert r >= 0.0
        # identical pair is always acceptable at any tolerance
        assert error_ratio(y_hi, y_hi, y_prev, atol, rtol) == 0.0
        # tightening BOTH tolerances by 10x scales the ratio by >= ~10x
        # (>= because the scale is atol + rtol*mag, not a pure product)
        r10 = error_ratio(y_hi, y_lo, y_prev, atol / 10, rtol / 10)
        assert r10 == pytest.approx(10 * r, rel=1e-9)


# ------------------------------------------------------------- step_factor
def test_step_factor_shape():
    assert step_factor(1.0) == pytest.approx(0.9)       # on the boundary
    assert step_factor(0.0) == 5.0                      # max growth, clipped
    assert step_factor(1e12) == 0.2                     # max shrink, clipped
    # third-order rescale inside the clip band
    assert step_factor(0.5) == pytest.approx(0.9 * 0.5 ** (-1 / 3))


def test_step_factor_monotone_and_contracts_on_reject():
    errs = 10.0 ** np.linspace(-6, 4, 40)
    fac = [step_factor(e) for e in errs]
    assert all(a >= b for a, b in zip(fac, fac[1:]))    # non-increasing
    for e in errs:
        if e > 1.0:          # rejected step MUST shrink
            assert step_factor(e) < 1.0
        assert 0.2 <= step_factor(e) <= 5.0


# ------------------------------------------------------------ RetirePolicy
def test_retire_policy_validation():
    with pytest.raises(ValueError):
        RetirePolicy(tol=0.0)
    with pytest.raises(ValueError):
        RetirePolicy(tol=-1e-3)
    with pytest.raises(ValueError):
        RetirePolicy(tol=1e-3, min_k=0)
    with pytest.raises(ValueError):
        RetirePolicy(tol=1e-3, norm="l2")
    with pytest.raises(ValueError):
        RetirePolicy(tol=1e-3, norm="rel").converged(np.array([0.0]))


def test_retire_policy_converged_abs_rel_and_inf():
    err = np.array([1e-5, 1e-2, np.inf, np.nan])
    pol = RetirePolicy(tol=1e-3)
    # inf (no estimate yet) and nan never converge, whatever the tol
    np.testing.assert_array_equal(pol.converged(err),
                                  [True, False, False, False])
    np.testing.assert_array_equal(
        RetirePolicy(tol=1e9).converged(err), [True, True, False, False])
    # rel: bound scales with each row's own magnitude
    rel = RetirePolicy(tol=1e-3, norm="rel")
    x_inf = np.array([1.0, 100.0, 1.0, 1.0])
    np.testing.assert_array_equal(rel.converged(err, x_inf),
                                  [True, True, False, False])
    # degenerate zero-magnitude rows fall back to a floor, not a zero bound
    assert rel.converged(np.array([0.0]), np.array([0.0]))[0]


# ----------------------------------------- AdaptiveRK23 controller accounting
@pytest.fixture(scope="module")
def sde():
    return VPSDE()


def test_adaptive_rk23_nfe_accounting(sde):
    """Every attempt (accepted OR rejected) costs exactly 3 evals on top of
    the initial FSAL seed -- the accounting the paper's App. B Q2 argument
    (rejections waste NFE) depends on."""
    def eps(x, t):
        return jnp.tanh(x) * jnp.cos(t)

    x_T = jax.random.normal(jax.random.PRNGKey(0), (2, 8))
    res = AdaptiveRK23(sde, rtol=1e-3, atol=1e-3).solve(eps, x_T)
    assert res.nfe == 1 + 3 * (res.n_accepted + res.n_rejected)
    assert res.n_accepted >= 1
    assert int(res.state.k) == res.n_accepted
    assert res.x0.shape == x_T.shape
    # the solve left a genuine last-pair estimate behind
    assert np.isfinite(float(res.state.err))


def test_adaptive_rk23_exact_rhs_never_rejects(sde):
    """eps == 0 makes the rho-ODE trivial (y' = 0): the embedded pair agrees
    exactly, so the controller must accept every step at max growth and
    return x0 = mu(t0)/mu(T) * x_T unchanged."""
    x_T = jnp.ones((4,)) * 0.7
    res = AdaptiveRK23(sde, rtol=1e-6, atol=1e-6).solve(
        lambda x, t: jnp.zeros_like(x), x_T)
    assert res.n_rejected == 0
    scale = float(sde.mu(sde.t0)) / float(sde.mu(sde.T))
    np.testing.assert_allclose(np.asarray(res.x0), scale * np.asarray(x_T),
                               rtol=1e-12)
    assert float(res.state.err) == 0.0


def test_adaptive_rk23_tighter_tol_more_steps(sde):
    def eps(x, t):
        return jnp.sin(3 * x) * jnp.exp(-t)

    x_T = jax.random.normal(jax.random.PRNGKey(1), (8,))
    loose = AdaptiveRK23(sde, rtol=1e-1, atol=1e-1).solve(eps, x_T)
    tight = AdaptiveRK23(sde, rtol=1e-4, atol=1e-4).solve(eps, x_T)
    assert tight.n_accepted > loose.n_accepted
    assert tight.nfe > loose.nfe


# --------------------------------- SamplerState.err estimate semantics (the
# machinery RetirePolicy consumes through the serving engine)
def _eps(x, t):
    t = jnp.reshape(t, jnp.shape(t) + (1,) * (x.ndim - jnp.ndim(t)))
    return jnp.sin(x) * 0.1 + 0.01 * t


@pytest.mark.parametrize("solver", ["tab2", "tab3", "ipndm3", "rho_heun",
                                    "dpm2", "pndm"])
def test_err_estimate_never_perturbs_iterate(sde, solver):
    """error_estimate=True must be free: bitwise-identical x trajectory and
    zero extra NFE vs the same plan without estimates (early-exit serving
    builds every plan with estimates on; a perturbation here would break
    bitwise-vs-solo against estimate-off engines AND the paper's tables)."""
    ts = get_timesteps(sde, 8, "uniform")
    base = make_plan(solver, sde, ts)
    est = make_plan(solver, sde, ts, error_estimate=True)
    assert base.nfe == est.nfe
    assert not base.error_estimate and est.error_estimate
    assert base.signature != est.signature       # distinct trace identities
    x_T = jax.random.normal(jax.random.PRNGKey(2), (2, 6))
    s0, s1 = init_state(base, x_T), init_state(est, x_T)
    for k in range(base.n_steps):
        s0 = step(base, k, s0, _eps)
        s1 = step(est, k, s1, _eps)
    np.testing.assert_array_equal(np.asarray(s0.x), np.asarray(s1.x))


@pytest.mark.parametrize("solver,first_k", [("tab3", 4), ("rho_heun", 1),
                                            ("pndm", 4)])
def test_err_inf_until_first_genuine_estimate(sde, solver, first_k):
    """err is +inf at init and through warmup (both embedded orders coincide
    there: no information), then finite from the first genuine pair --
    exactly the rows RetirePolicy.converged refuses to retire."""
    ts = get_timesteps(sde, 8, "uniform")
    plan = make_plan(solver, sde, ts, error_estimate=True)
    st = init_state(plan, jax.random.normal(jax.random.PRNGKey(3), (2, 6)))
    assert np.isinf(float(st.err))
    for k in range(plan.n_steps):
        st = step(plan, k, st, _eps)
        if k + 1 < first_k:
            assert np.isinf(float(st.err)), (solver, k)
        else:
            assert np.isfinite(float(st.err)) and float(st.err) > 0.0


def test_err_without_estimate_flag_stays_inf(sde):
    ts = get_timesteps(sde, 6, "uniform")
    plan = make_plan("tab2", sde, ts)          # default: no embedded pair
    st = init_state(plan, jnp.ones((2, 4)))
    for k in range(plan.n_steps):
        st = step(plan, k, st, _eps)
    assert np.isinf(float(st.err))
    # ... and RetirePolicy can therefore never fire on it
    assert not RetirePolicy(tol=1e30).converged(np.asarray(st.err)).any()
