"""repro.analysis: fixture-driven checker contracts, suppression syntax,
CLI exit codes, the bench record, and the live-tree self-check (the
committed src/ must stay clean modulo its justified allow comments)."""
import json
from pathlib import Path

import pytest

from repro.analysis import RULES, analyze, main, write_bench

FIX = Path(__file__).parent / "analysis_fixtures"
SRC = Path(__file__).resolve().parents[1] / "src"

# (rule, fixture stem, expected violation count in the known-bad file);
# counts are exact so a checker that silently stops firing breaks loudly.
CASES = [("RL001", "rl001", 7), ("RL002", "rl002", 6),
         ("RL003", "rl003", 4), ("RL004", "rl004", 5),
         ("RL005", "rl005", 4)]


@pytest.mark.parametrize("rule,stem,expected", CASES)
def test_bad_fixture_flags(rule, stem, expected):
    report = analyze([str(FIX / f"{stem}_bad.py")])
    assert report.exit_code == 1
    assert report.counts()[rule] == expected
    assert {v.rule for v in report.active} == {rule}


@pytest.mark.parametrize("rule,stem,expected", CASES)
def test_good_fixture_clean(rule, stem, expected):
    report = analyze([str(FIX / f"{stem}_good.py")])
    assert report.exit_code == 0 and not report.violations


def test_suppression_allows_but_reports():
    report = analyze([str(FIX / "suppressed.py")])
    assert report.exit_code == 0
    assert [v.rule for v in report.allowed] == ["RL001"]
    assert "[allowed]" in report.human()


def test_cli_exit_codes(tmp_path, capsys):
    assert main([str(FIX / "rl001_good.py")]) == 0
    assert main([str(FIX / "rl001_bad.py")]) == 1
    assert main(["--rules", "NOPE", str(FIX)]) == 2
    assert main([str(tmp_path / "missing.txt")]) == 2
    capsys.readouterr()


def test_cli_rule_subset(capsys):
    # RL001 findings are invisible to an RL002-only run
    assert main(["--rules", "RL002", str(FIX / "rl001_bad.py")]) == 0
    capsys.readouterr()


def test_cli_json(capsys):
    main(["--json", str(FIX / "rl003_bad.py")])
    data = json.loads(capsys.readouterr().out)
    assert data["counts"]["RL003"] == 4
    v = data["violations"][0]
    assert {"rule", "path", "line", "col", "message", "allowed"} <= set(v)


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert all(rule in out for rule in RULES)


def test_syntax_error_is_rl000(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def oops(:\n")
    report = analyze([str(bad)])
    assert report.exit_code == 1
    assert [v.rule for v in report.active] == ["RL000"]


def test_bench_record(tmp_path):
    out = tmp_path / "BENCH_static.json"
    report = analyze([str(FIX / "rl002_bad.py")])
    write_bench(report, str(out), ["fixtures"])
    rec = json.loads(out.read_text())
    m = rec["metrics"]["static.RL002.violations"]
    assert m["value"] == 6 and m["ratchet"] and m["tol"] == 0.0
    assert m["direction"] == "lower"
    assert rec["metrics"]["static.files"]["value"] == 1
    assert rec["meta"]["rules"] == list(RULES)


def test_live_tree_clean(capsys):
    """The committed src/ passes the analyzer -- same invocation as CI's
    lint job. Any new violation must be fixed or carry a justified
    ``# repro: allow[RULE]``."""
    code = main([str(SRC)])
    out = capsys.readouterr().out
    assert code == 0, out
