"""Per-architecture smoke tests (assignment requirement): a REDUCED variant of
each family runs one forward + one train step on CPU; output shapes asserted,
no NaNs. Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.data.pipeline import make_batch, MarkovTextSource
from repro.models import transformer as T
from repro.training.optimizer import AdamW, constant_schedule
from repro.training.steps import make_train_step, make_prefill_step, make_decode_step

pytestmark = pytest.mark.slow  # model-zoo sweep: one forward + train step per architecture

ARCHS = [a for a in ARCH_IDS if a != "cifar10_scorenet"]


def _setup(arch, objective):
    cfg = get_config(arch).reduced().with_(objective=objective)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    src = MarkovTextSource(cfg.vocab_size, seed=1)
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, src, 0, batch=2, seq=32).items()}
    return cfg, params, batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 8 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("objective", ["ar", "diffusion"])
def test_one_train_step(arch, objective):
    cfg, params, batch = _setup(arch, objective)
    opt = AdamW(constant_schedule(1e-3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    params2, opt_state2, metrics = step(params, opt_state, batch,
                                        jax.random.PRNGKey(2))
    assert np.isfinite(float(metrics["loss"])), (arch, objective)
    assert np.isfinite(float(metrics["grad_norm"]))
    # params changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert l0.shape == l1.shape
    assert not np.allclose(np.asarray(l0, np.float32), np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg, params, batch = _setup(arch, "ar")
    out = T.forward(params, cfg, tokens=batch["tokens"], mode="train",
                    prefix=batch.get("prefix"), frames=batch.get("frames"))
    b, s = batch["tokens"].shape
    extra = cfg.prefix_tokens if cfg.arch_type == "vlm" else 0
    assert out["logits"].shape == (b, s + extra, cfg.vocab_size)
    assert np.isfinite(np.asarray(out["logits"], np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full_forward(arch):
    """KV-cache correctness: decode logits == full-forward logits at the last
    position (MoE capacity raised so no tokens drop; the comparison is exact
    semantics, not approximation)."""
    cfg, params, batch = _setup(arch, "ar")
    if cfg.moe is not None:
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    tok = batch["tokens"]
    b, s1 = tok.shape
    s = s1 - 1
    kw = {k: batch[k] for k in ("prefix", "frames") if k in batch}
    full = T.forward(params, cfg, tokens=tok, mode="train", **kw)
    pf = T.forward(params, cfg, tokens=tok[:, :s], mode="prefill", **kw)
    cache = dict(pf["cache"])
    p = cfg.prefix_tokens if cfg.arch_type == "vlm" else 0

    def pad_kv(path, leaf):
        name = jax.tree_util.keystr(path)
        is_kv = name.endswith("['k']") or name.endswith("['v']")
        if is_kv and leaf.ndim == 5 and not (
                cfg.sliding_window and leaf.shape[2] == cfg.sliding_window):
            padw = [(0, 0)] * 5
            padw[2] = (0, 1)
            return jnp.pad(leaf, padw)
        return leaf

    cache["blocks"] = jax.tree_util.tree_map_with_path(pad_kv, cache["blocks"])
    dec = T.forward(params, cfg, tokens=tok[:, s:], mode="decode",
                    cache=cache, cache_index=jnp.int32(s + p))
    a = np.asarray(full["logits"][:, -1], np.float32)
    b_ = np.asarray(dec["logits"][:, -1], np.float32)
    np.testing.assert_allclose(a, b_, rtol=2e-3, atol=2e-3 * np.abs(a).max())


def test_swa_ring_buffer_decode_matches_full():
    """Sliding-window ring cache: long decode sequence, window < seq."""
    cfg = get_config("h2o_danube_3_4b").reduced().with_(objective="ar")
    assert cfg.sliding_window == 16
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, 41), 0, cfg.vocab_size)
    s = 40
    full = T.forward(params, cfg, tokens=tok, mode="train")
    pf = T.forward(params, cfg, tokens=tok[:, :s], mode="prefill")
    dec = T.forward(params, cfg, tokens=tok[:, s:], mode="decode",
                    cache=pf["cache"], cache_index=jnp.int32(s))
    np.testing.assert_allclose(np.asarray(full["logits"][:, -1], np.float32),
                               np.asarray(dec["logits"][:, -1], np.float32),
                               rtol=2e-3, atol=1e-3)


def test_hybrid_layer_pattern():
    cfg = get_config("jamba_1p5_large")
    kinds = ["attn" if cfg.is_attn_layer(i) else "ssm"
             for i in range(cfg.attn_every)]
    assert kinds.count("attn") == 1  # 1:7 attention:mamba (arXiv:2403.19887)
    assert cfg.is_moe_layer(1) and not cfg.is_moe_layer(0)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 some tokens drop but output stays finite and
    the load-balance loss is positive."""
    cfg = get_config("mixtral_8x7b").reduced().with_(objective="ar")
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    out = T.forward(params, cfg, tokens=tok, mode="train")
    assert np.isfinite(np.asarray(out["logits"], np.float32)).all()
    assert float(out["aux"]["moe_lb"]) > 0


def test_diffusion_lm_sampling_roundtrip():
    """Train-free check: DEIS sampling through a random reduced backbone
    produces tokens of the right shape with finite embeddings."""
    from repro.core import VPSDE, get_timesteps, make_plan
    from repro.diffusion import lm as DLM
    cfg = get_config("gemma_2b").reduced()  # diffusion objective default off;
    cfg = cfg.with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    sde = VPSDE()
    plan = make_plan("tab2", sde, get_timesteps(sde, 6, "quadratic"))
    toks, x0 = DLM.sample_tokens(params, cfg, plan, jax.random.PRNGKey(1),
                                 batch=2, seq_len=16,
                                 prior_std=sde.prior_std())
    assert toks.shape == (2, 16)
    assert np.isfinite(np.asarray(x0)).all()


@pytest.mark.parametrize("arch", ["gemma_2b", "h2o_danube_3_4b", "mamba2_2p7b"])
def test_pallas_kernel_routing_matches_xla(arch):
    """use_pallas=True routes attention/SSD through the Pallas kernels
    (interpret mode on CPU) and must match the XLA path."""
    cfg = get_config(arch).reduced().with_(objective="ar")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    a = T.forward(params, cfg, tokens=tok, mode="train")["logits"]
    b = T.forward(params, cfg, tokens=tok, mode="train",
                  use_pallas=True)["logits"]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-3, atol=2e-3)
