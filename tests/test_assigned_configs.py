"""Pin the EXACT assigned architecture configurations (public-pool citations).
Any drift from the assignment sheet fails here."""
import pytest

from repro.configs.base import get_config

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
    "h2o_danube_3_4b": (24, 3840, 32, 8, 10240, 32000),
    "paligemma_3b": (18, 2048, 8, 1, 16384, 257216),
    "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
    "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
    "mamba2_2p7b": (64, 2560, 1, 1, 0, 50280),
    "glm4_9b": (40, 4096, 32, 2, 13696, 151552),
    "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
    "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
    "jamba_1p5_large": (72, 8192, 64, 8, 24576, 65536),
}


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_assigned_numbers(arch):
    cfg = get_config(arch)
    l, d, h, kv, ff, v = EXPECTED[arch]
    assert cfg.n_layers == l
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.source  # must cite the pool entry


def test_moe_settings():
    mix = get_config("mixtral_8x7b")
    assert mix.moe.num_experts == 8 and mix.moe.top_k == 2
    assert mix.sliding_window == 4096
    grok = get_config("grok_1_314b")
    assert grok.moe.num_experts == 8 and grok.moe.top_k == 2
    jam = get_config("jamba_1p5_large")
    assert jam.moe.num_experts == 16 and jam.moe.top_k == 2
    assert jam.attn_every == 8  # 1:7 mamba:attention


def test_ssm_settings():
    m = get_config("mamba2_2p7b")
    assert m.ssm.state_dim == 128
    assert m.arch_type == "ssm"


def test_frontend_stubs():
    w = get_config("whisper_tiny")
    assert w.arch_type == "encdec" and w.encoder_seq == 1500
    p = get_config("paligemma_3b")
    assert p.arch_type == "vlm" and p.prefix_tokens == 256
    assert p.resolved_head_dim == 256  # gemma-style


@pytest.mark.slow  # instantiates full-size (non-reduced) model params
def test_param_counts_roughly_match_names():
    """Sanity: total parameter counts land near the advertised sizes."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models.transformer import init_params

    def count(arch):
        cfg = get_config(arch).with_(objective="ar")
        shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                                jax.ShapeDtypeStruct((2,), jnp.uint32))
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))

    assert 250e9 < count("grok_1_314b") < 380e9
    assert 35e9 < count("mixtral_8x7b") < 55e9
    assert 2.0e9 < count("gemma_2b") < 3.2e9
    assert 2.2e9 < count("mamba2_2p7b") < 3.4e9
    assert 300e9 < count("jamba_1p5_large") < 480e9


def test_config_from_dict_strict_converter():
    """The local dict->dataclass converter (dacite replacement): nested
    dataclasses recurse, unknown keys raise, type mismatches raise."""
    from repro.configs.base import MoEConfig, config_from_dict

    cfg = config_from_dict({"name": "m", "n_layers": 2, "d_model": 64,
                            "moe": {"num_experts": 4, "top_k": 1},
                            "rope_theta": 10000})
    assert cfg.n_layers == 2
    assert isinstance(cfg.moe, MoEConfig) and cfg.moe.num_experts == 4
    assert cfg.rope_theta == 10000.0 and isinstance(cfg.rope_theta, float)
    assert config_from_dict({"ssm": None}).ssm is None

    with pytest.raises(ValueError, match="unknown keys"):
        config_from_dict({"not_a_field": 1})
    with pytest.raises(ValueError, match="unknown keys"):
        config_from_dict({"moe": {"bogus": 1}})
    with pytest.raises(TypeError):
        config_from_dict({"n_layers": "four"})
