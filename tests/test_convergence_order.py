"""Empirical convergence order for EVERY registered solver family.

The numerical ground truth that coefficient tables are right: on the
analytic Gaussian oracle (``diffusion/analytic.py`` -- exact eps, exact
PF-ODE flow, zero fitting error) each solver's error at N and 2N steps
yields its observed order ``log2(err_N / err_2N)``; the test asserts
observed >= nominal - 0.5 for every ``SOLVER_NAMES`` entry, old and new.

Two measurement regimes:

* deterministic plans -- RMSE against the closed-form PF-ODE transport
  ``GaussianData.exact_flow``;
* stochastic plans (em / ddim_eta / seeds*) -- the noise scale is the
  per-step ``s`` coefficient leaf; zeroing it leaves the family's
  deterministic backbone, and em, eta-DDIM and SEEDS all discretize the
  SAME doubled-eps-drift reverse-SDE ODE ``dx = [f x + (g^2/sigma) eps] dt``
  (exponential integrators of it, for SEEDS), so one fine zero-noise
  seeds3 solve is the common reference. The backbone order equals the
  solver's deterministic order of strong accuracy.

Each family is measured on its natural schedule: lambda-basis families
(dpm*m, seeds*) on ``log_rho`` (uniform in half-log-SNR, the grid the
DPM-Solver papers use), everything else on ``uniform``. Grids are chosen
inside the asymptotic regime but above the float32 sampling floor; a plan
whose error is already at the floor on every grid (eta-DDIM is exact for
Gaussian data) passes as "exact to measurement precision".
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (VPSDE, SOLVER_NAMES, get_timesteps, init_state,
                        make_plan, sample, step)
from repro.diffusion.analytic import GaussianData

SDE = VPSDE()
KEY = jax.random.PRNGKey(11)
FLOOR = 2e-5          # float32 sampling floor (ref self-consistency ~2e-6)


@dataclasses.dataclass(frozen=True)
class Case:
    nominal: float          # guaranteed order of accuracy
    schedule: str           # grid family the order is measured on
    grids: tuple            # (N, 2N, 4N): errors at N and 2N (and 4N)


CASES = {
    # DEIS / exponential-integrator AB families (paper Tab. 2)
    "ddim": Case(1, "uniform", (8, 16, 32)),
    "tab1": Case(2, "uniform", (8, 16, 32)),
    "tab2": Case(3, "uniform", (8, 16, 32)),
    "tab3": Case(4, "uniform", (8, 16, 32)),
    "rhoab1": Case(2, "uniform", (8, 16, 32)),
    "rhoab2": Case(3, "uniform", (8, 16, 32)),
    "rhoab3": Case(4, "uniform", (8, 16, 32)),
    # rho-ODE Runge-Kutta
    "rho_heun": Case(2, "uniform", (8, 16, 32)),
    "rho_midpoint": Case(2, "uniform", (8, 16, 32)),
    "rho_kutta3": Case(3, "uniform", (8, 16, 32)),
    "rho_rk4": Case(4, "uniform", (4, 8, 16)),   # small N: f32 floor at 32
    "dpm2": Case(2, "uniform", (8, 16, 32)),
    # baselines
    "euler": Case(1, "uniform", (16, 32, 64)),
    "naive_ei": Case(1, "uniform", (8, 16, 32)),
    # (i)PNDM
    "ipndm1": Case(2, "uniform", (8, 16, 32)),
    "ipndm2": Case(3, "uniform", (8, 16, 32)),
    "ipndm3": Case(4, "uniform", (8, 16, 32)),
    "pndm": Case(2, "uniform", (8, 16, 32)),
    # DPM-Solver multistep: lambda-basis AB, measured on its natural
    # uniform-in-lambda grid (on uniform-t the lambda steps near t0 are too
    # ragged for the asymptotic regime at test-sized N)
    "dpm2m": Case(2, "log_rho", (16, 32, 64)),
    "dpm3m": Case(3, "log_rho", (16, 32, 64)),
    # SciRE (rd_m=1 recursive-difference factor: classical orders)
    "scire2": Case(2, "uniform", (8, 16, 32)),
    "scire3": Case(3, "uniform", (8, 16, 32)),
    # score-normalized DEIS (order r polynomial -> order r+1)
    "sndeis1": Case(2, "uniform", (16, 32, 64)),
    "sndeis2": Case(3, "uniform", (8, 16, 32)),
    "sndeis3": Case(4, "uniform", (32, 64, 128)),
    # stochastic: deterministic-backbone order (noise leaf zeroed)
    "em": Case(1, "uniform", (16, 32, 64)),
    "ddim_eta": Case(1, "uniform", (16, 32, 64)),  # exact here: floor rule
    "seeds1": Case(1, "log_rho", (64, 128, 256)),  # small constant, slow onset
    "seeds2": Case(2, "log_rho", (16, 32, 64)),
    # seeds3 backbone measures ~2.4 at test N: the self-starting warmup's
    # first steps run at lower degree (local O(h^2)) and the doubled drift
    # keeps that tail visible; the degree-2 lambda-AB tables themselves are
    # order-3-verified via dpm3m (identical machinery, single drift).
    "seeds3": Case(2.5, "log_rho", (32, 64, 128)),
}


def test_every_solver_name_has_a_case():
    """A new SOLVER_NAMES entry without a convergence case is a test gap --
    this is the registration guard the ISSUE's harness hinges on."""
    assert set(CASES) == set(SOLVER_NAMES)


def _problem(d=4, batch=64):
    g = GaussianData(SDE, mean=np.full(d, 1.5), var=np.full(d, 0.25))
    xT = jax.random.normal(jax.random.PRNGKey(0), (batch, d)) * SDE.prior_std()
    return g.eps_fn(), xT


def _mk(name, n, schedule, **kw):
    if name == "ddim_eta":
        kw.setdefault("eta", 1.0)
    return make_plan(name, SDE, get_timesteps(SDE, n, schedule), **kw)


def _denoised(plan):
    """The stochastic plan's deterministic backbone: noise scale leaf -> 0."""
    c = dict(plan.coeffs)
    c["s"] = jnp.zeros_like(jnp.asarray(c["s"]))
    return dataclasses.replace(plan, coeffs=c)


_CACHE = {}


def _references():
    """(exact PF-ODE flow, fine zero-noise doubled-drift reference)."""
    if "refs" not in _CACHE:
        eps, xT = _problem()
        d = xT.shape[-1]
        g = GaussianData(SDE, mean=np.full(d, 1.5), var=np.full(d, 0.25))
        exact = g.exact_flow(xT, SDE.T, SDE.t0)
        sde_ref = sample(_denoised(_mk("seeds3", 512, "log_rho")), eps, xT,
                         KEY)
        _CACHE["refs"] = (np.asarray(exact), np.asarray(sde_ref))
    return _CACHE["refs"]


def _err(name, n, schedule):
    eps, xT = _problem()
    exact, sde_ref = _references()
    plan = _mk(name, n, schedule)
    if plan.stochastic:
        x = sample(_denoised(plan), eps, xT, KEY)
        ref = sde_ref
    else:
        x = sample(plan, eps, xT)
        ref = exact
    return float(np.sqrt(np.mean((np.asarray(x) - ref) ** 2)))


@pytest.mark.parametrize("name", sorted(CASES))
def test_convergence_order(name):
    case = CASES[name]
    errs = [_err(name, n, case.schedule) for n in case.grids]
    if max(errs) < FLOOR:       # exact to measurement precision (ddim_eta)
        return
    orders = [np.log2(errs[i] / errs[i + 1]) for i in range(len(errs) - 1)]
    assert np.mean(orders) >= case.nominal - 0.5, (name, errs, orders)


# --------------------------------------------------- embedded error pairs
_PAIRED = ["tab2", "tab3", "dpm2m", "dpm3m", "scire2", "scire3",
           "sndeis2", "sndeis3"]


@pytest.mark.parametrize("name", _PAIRED)
def test_embedded_error_estimate_tracks_step_refinement(name):
    """Families that admit an embedded lower-order pair: the running
    ``SamplerState.err`` estimate is finite, positive, and shrinks as the
    grid refines -- the property serving's RetirePolicy consumes."""
    eps, xT = _problem(batch=8)
    case = CASES[name]
    ests = []
    for n in (8, 32):
        plan = make_plan(name, SDE, get_timesteps(SDE, n, case.schedule),
                         error_estimate=True)
        assert plan.error_estimate
        st = init_state(plan, xT, KEY)
        for k in range(plan.n_steps):
            st = step(plan, k, st, eps)
        est = float(st.err)
        assert np.isfinite(est) and est > 0, (name, n, est)
        ests.append(est)
    assert ests[1] < ests[0], (name, ests)
