"""SDE schedule self-consistency + schedule properties (unit + property tests)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import VPSDE, VESDE, SubVPSDE, get_sde, get_timesteps, SCHEDULES

SDES = [VPSDE(), VESDE(sigma_max=50.0), SubVPSDE()]


@pytest.mark.parametrize("sde", SDES, ids=lambda s: type(s).__name__)
class TestSDEConsistency:
    def test_drift_matches_mu(self, sde):
        """f(t) must equal d log mu / dt (the EI linear term is exact only then)."""
        t = np.linspace(0.05, 0.95, 9)
        h = 1e-6
        f_num = (np.log(sde.mu(t + h)) - np.log(sde.mu(t - h))) / (2 * h)
        np.testing.assert_allclose(sde.f(t), f_num, rtol=1e-6, atol=1e-7)

    def test_diffusion_matches_sigma(self, sde):
        """g^2 = d sigma^2/dt - 2 f sigma^2 (forward variance evolution)."""
        t = np.linspace(0.05, 0.95, 9)
        h = 1e-6
        ds2 = (sde.sigma(t + h) ** 2 - sde.sigma(t - h) ** 2) / (2 * h)
        np.testing.assert_allclose(sde.g2(t), ds2 - 2 * sde.f(t) * sde.sigma(t) ** 2,
                                   rtol=1e-4, atol=1e-6)

    def test_rho_roundtrip(self, sde):
        t = np.linspace(0.02, 0.98, 17)
        np.testing.assert_allclose(sde.t_of_rho(sde.rho(t)), t, rtol=1e-8, atol=1e-8)

    def test_rho_monotone_increasing(self, sde):
        t = np.linspace(0.01, 1.0, 50)
        assert np.all(np.diff(sde.rho(t)) > 0)


def test_vpsde_alpha_bar_limits():
    sde = VPSDE()
    assert abs(sde.alpha_bar(0.0) - 1.0) < 1e-12
    assert sde.alpha_bar(1.0) < 5e-5  # alpha_T ~ 0 (paper Tab. 1)
    assert abs(sde.prior_std() - 1.0) < 1e-12


def test_get_sde_factory():
    assert isinstance(get_sde("vp"), VPSDE)
    assert isinstance(get_sde("ve"), VESDE)
    with pytest.raises(ValueError):
        get_sde("nope")


@pytest.mark.parametrize("name", sorted(SCHEDULES))
@pytest.mark.parametrize("sde", SDES, ids=lambda s: type(s).__name__)
def test_schedules_decreasing_with_endpoints(name, sde, subtests=None):
    ts = get_timesteps(sde, 17, name)
    assert len(ts) == 18
    assert ts[0] == pytest.approx(sde.T)
    assert ts[-1] == pytest.approx(sde.t0, rel=1e-6)
    assert np.all(np.diff(ts) < 0)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(4, 200), kappa=st.floats(1.0, 8.0),
       t0=st.floats(1e-5, 1e-2))
def test_power_t_schedule_properties(n, kappa, t0):
    from repro.core.schedules import power_t
    sde = VPSDE()
    ts = power_t(sde, n, t0, kappa)
    assert np.all(np.diff(ts) < 0)
    assert ts[0] == pytest.approx(sde.T) and ts[-1] == pytest.approx(t0, rel=1e-6)
    if kappa > 1.001:
        # larger kappa concentrates steps near t0 (Ingredient 4 rationale)
        steps = -np.diff(ts)
        assert steps[-1] < steps[0]


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 100))
def test_log_rho_is_geometric_in_rho(n):
    sde = VESDE(sigma_max=50.0)
    ts = get_timesteps(sde, n, "log_rho")
    rho = sde.rho(ts)
    ratios = rho[1:] / rho[:-1]
    np.testing.assert_allclose(ratios, ratios[0], rtol=1e-6)
