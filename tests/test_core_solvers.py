"""Solver correctness: Prop. 2, convergence orders, paper-claim orderings.

These are the *faithful reproduction* gates: each test pins one of the paper's
mathematical claims (not a vibe -- an assertion).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (VPSDE, VESDE, get_timesteps, ab_coefficients,
                        ddim_coefficients_vp, make_plan, sample)
from repro.core.coeffs import AB_WEIGHTS
from repro.diffusion.analytic import GaussianData, default_gmm

SDE = VPSDE()


def _gaussian_problem(d=4, batch=64):
    g = GaussianData(SDE, mean=np.full(d, 1.5), var=np.full(d, 0.25))
    xT = jax.random.normal(jax.random.PRNGKey(0), (batch, d)) * SDE.prior_std()
    exact = g.exact_flow(xT, SDE.T, SDE.t0)
    return g.eps_fn(), xT, exact


def _err(solver_name, eps, xT, exact, n, schedule="uniform"):
    plan = make_plan(solver_name, SDE, get_timesteps(SDE, n, schedule))
    return float(jnp.sqrt(jnp.mean((sample(plan, eps, xT) - exact) ** 2)))


# ---------------------------------------------------------------- Prop. 2
def test_prop2_tab0_equals_closed_form_ddim():
    """tAB-DEIS with r=0 == deterministic DDIM, to machine precision."""
    for schedule in ("uniform", "quadratic", "log_rho"):
        ts = get_timesteps(SDE, 13, schedule)
        p1, c1 = ab_coefficients(SDE, ts, 0, "t")
        p2, c2 = ddim_coefficients_vp(SDE, ts)
        np.testing.assert_allclose(p1, p2, rtol=1e-12)
        np.testing.assert_allclose(c1, c2, rtol=0, atol=1e-13)


def test_tab0_equals_rhoab0():
    """Zero-order: basis choice is irrelevant (constant polynomial)."""
    ts = get_timesteps(SDE, 9, "quadratic")
    _, ct = ab_coefficients(SDE, ts, 0, "t")
    _, cr = ab_coefficients(SDE, ts, 0, "rho")
    np.testing.assert_allclose(ct, cr, rtol=1e-12)


def test_ddim_eta0_equals_tab0_samples():
    eps, xT, _ = _gaussian_problem()
    ts = get_timesteps(SDE, 10, "quadratic")
    a = sample(make_plan("ddim", SDE, ts), eps, xT)
    b = sample(make_plan("ddim_eta", SDE, ts, eta=0.0), eps, xT,
               jax.random.PRNGKey(1))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-9, atol=1e-9)


# ------------------------------------------------------- convergence orders
@pytest.mark.parametrize("name,expected,tol", [
    ("ddim", 1.0, 0.25), ("tab1", 2.0, 0.45), ("tab2", 3.0, 0.6),
    ("rhoab1", 2.0, 0.45), ("rhoab2", 3.0, 0.6),
    ("rho_heun", 2.0, 0.25), ("rho_midpoint", 2.0, 0.3),
    ("rho_kutta3", 3.0, 0.4), ("euler", 1.0, 0.3), ("naive_ei", 1.0, 0.25),
    ("dpm2", 2.0, 0.3),
])
def test_convergence_order(name, expected, tol):
    """Order of accuracy on the exactly-solvable Gaussian PF-ODE."""
    eps, xT, exact = _gaussian_problem()
    errs = [_err(name, eps, xT, exact, n) for n in (8, 16, 32)]
    orders = [np.log2(errs[i] / errs[i + 1]) for i in range(2)]
    # one-sided with superconvergence allowance (midpoint gains an order on
    # symmetric linear problems)
    assert np.mean(orders) > expected - tol, (errs, orders)
    assert np.mean(orders) < expected + 1.3, (errs, orders)


def test_high_order_beats_ddim_at_low_nfe():
    """Paper: 'DEIS with high-order polynomial approximation significantly
    outperforms DDIM' (Tab. 2)."""
    eps, xT, exact = _gaussian_problem()
    for n in (5, 10, 20):
        e0 = _err("ddim", eps, xT, exact, n, "quadratic")
        e3 = _err("tab3", eps, xT, exact, n, "quadratic")
        assert e3 < e0, (n, e0, e3)
        assert _err("tab2", eps, xT, exact, n, "quadratic") < e0


def test_order_monotonicity_tab():
    """tAB3 <= tAB2 <= tAB1 <= tAB0 at N=10 (paper Tab. 2 column ordering)."""
    eps, xT, exact = _gaussian_problem()
    errs = [_err(f"tab{r}" if r else "ddim", eps, xT, exact, 10, "quadratic")
            for r in range(4)]
    assert errs[3] < errs[2] < errs[1] < errs[0], errs


def test_fig3_ordering_naive_ei_vs_euler_vs_eps_ei():
    """Fig. 3 / Ingredients 1-2 on concentrated data (paper Fig. 2 toy:
    'Gaussian concentrated with a very small variance'): naive EI (score
    parameterization, frozen L_t) is WORSE than Euler, while EI with the
    eps-parameterization (== DDIM) is far better than both."""
    d = 4
    g = GaussianData(SDE, mean=np.full(d, 1.5), var=np.full(d, 1e-4))
    eps = g.eps_fn()
    xT = jax.random.normal(jax.random.PRNGKey(0), (64, d)) * SDE.prior_std()
    exact = g.exact_flow(xT, SDE.T, SDE.t0)
    for n in (10, 20, 40):
        e_naive = _err("naive_ei", eps, xT, exact, n)
        e_euler = _err("euler", eps, xT, exact, n)
        e_ddim = _err("ddim", eps, xT, exact, n)
        assert e_naive > e_euler > e_ddim, (n, e_naive, e_euler, e_ddim)


def test_quadratic_schedule_beats_uniform_at_low_nfe():
    """Ingredient 4 on the GMM (rapid score change near t=0 matters there)."""
    gmm = default_gmm(SDE, d=2)
    eps = gmm.eps_fn()
    xT = jax.random.normal(jax.random.PRNGKey(2), (256, 2)) * SDE.prior_std()
    ref = sample(make_plan("rho_rk4", SDE, get_timesteps(SDE, 400, "log_rho")),
                 eps, xT)
    def err(sched):
        x = sample(make_plan("tab2", SDE, get_timesteps(SDE, 10, sched)), eps, xT)
        return float(jnp.sqrt(jnp.mean((x - ref) ** 2)))
    assert err("quadratic") < err("uniform")


# ----------------------------------------------------------- SDE samplers
def test_em_sampler_distribution_moments():
    """Euler-Maruyama (lambda=1) reproduces Gaussian data moments with many steps."""
    d = 2
    g = GaussianData(SDE, mean=np.full(d, 1.0), var=np.full(d, 0.3))
    eps = g.eps_fn()
    xT = jax.random.normal(jax.random.PRNGKey(3), (4096, d))
    plan = make_plan("em", SDE, get_timesteps(SDE, 200, "uniform"))
    x0 = sample(plan, eps, xT, jax.random.PRNGKey(4))
    assert np.allclose(np.asarray(x0).mean(0), 1.0, atol=0.08)
    assert np.allclose(np.asarray(x0).var(0), 0.3, atol=0.08)


def test_stochastic_ddim_moments():
    d = 2
    g = GaussianData(SDE, mean=np.full(d, -0.5), var=np.full(d, 0.5))
    eps = g.eps_fn()
    xT = jax.random.normal(jax.random.PRNGKey(5), (4096, d))
    plan = make_plan("ddim_eta", SDE, get_timesteps(SDE, 100, "quadratic"),
                     eta=1.0)
    x0 = sample(plan, eps, xT, jax.random.PRNGKey(6))
    assert np.allclose(np.asarray(x0).mean(0), -0.5, atol=0.08)
    assert np.allclose(np.asarray(x0).var(0), 0.5, atol=0.1)


# ------------------------------------------------------------- iPNDM/PNDM
def test_ipndm_matches_paper_ab_weights():
    np.testing.assert_allclose(AB_WEIGHTS[3], np.array([55, -59, 37, -9]) / 24.0)
    np.testing.assert_allclose(AB_WEIGHTS[2], np.array([23, -16, 5]) / 12.0)


def test_ipndm_beats_ddim():
    eps, xT, exact = _gaussian_problem()
    assert _err("ipndm3", eps, xT, exact, 10) < _err("ddim", eps, xT, exact, 10)


def test_pndm_nfe_accounting():
    ts = get_timesteps(SDE, 20, "uniform")
    assert make_plan("pndm", SDE, ts).nfe == 20 + 9
    assert make_plan("ipndm3", SDE, ts).nfe == 20
    assert make_plan("rho_heun", SDE, ts).nfe == 40
    assert make_plan("rho_rk4", SDE, ts).nfe == 80


# --------------------------------------------------------------- property
@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 60), order=st.integers(0, 3),
       basis=st.sampled_from(["t", "rho"]),
       schedule=st.sampled_from(["uniform", "quadratic", "log_rho"]))
def test_ab_coefficient_polynomial_exactness(n, order, basis, schedule):
    """The defining property of the DEIS-AB coefficients (Eq. 15): for any
    polynomial p of degree <= r in the basis variable,

        sum_j C[k, j] p(u_{k-j}) == mu(t_{k+1}) * \\int p(u(rho)) drho

    over each step interval -- i.e. the C_j are the exact EI-weighted
    integrals of the Lagrange interpolant."""
    sde = VPSDE()
    ts = get_timesteps(sde, n, schedule)
    _, C = ab_coefficients(sde, ts, order, basis)
    rho = np.asarray(sde.rho(ts))
    mu = np.asarray(sde.mu(ts))
    rng = np.random.RandomState(order * 101 + n)
    pcoef = rng.randn(order + 1)
    p = lambda u: sum(c * u ** k for k, c in enumerate(pcoef))
    from repro.core.coeffs import _gauss_legendre
    for k in range(order, min(n, order + 6)):  # past warmup rows
        u_hist = np.array([(rho if basis == "rho" else ts)[k - j] for j in range(order + 1)])
        lhs = float(np.sum(C[k] * p(u_hist)))
        q_rho, q_w = _gauss_legendre(rho[k], rho[k + 1], 64)
        q_u = q_rho if basis == "rho" else np.asarray(sde.t_of_rho(q_rho))
        rhs = float(mu[k + 1] * np.sum(q_w * p(q_u)))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-7, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_sampling_is_linear_in_state_for_linear_eps(seed):
    """With eps linear in x, every deterministic DEIS update is affine: check
    superposition x(a+b) - x(0) == (x(a)-x(0)) + (x(b)-x(0))."""
    eps, _, _ = _gaussian_problem()
    ts = get_timesteps(SDE, 8, "quadratic")
    plan = make_plan("tab2", SDE, ts)
    key = jax.random.PRNGKey(seed)
    a, b = jax.random.normal(key, (2, 1, 4))
    f = lambda z: sample(plan, eps, z)
    zero = f(jnp.zeros((1, 4)))
    lhs = f(a + b) - zero
    rhs = (f(a) - zero) + (f(b) - zero)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-6, atol=1e-8)
