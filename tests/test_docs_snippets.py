"""Execute the README's fenced ``python`` code blocks so the documented
quickstarts can never rot: every block runs top-to-bottom in one shared
namespace (like a reader pasting them into one session) and any failure —
import error, API drift, a broken headline assertion — fails CI's docs job.

Blocks fenced with any other language (``bash`` etc.) are skipped. A block
can opt out by being preceded by an HTML comment ``<!-- docs-test: skip -->``
(none currently do).
"""
import pathlib
import re

import pytest

# dedicated CI job (and still part of the full tier-1 run); excluded from the
# fast tier so the two jobs don't duplicate the README execution
pytestmark = pytest.mark.docs

README = pathlib.Path(__file__).resolve().parent.parent / "README.md"

_FENCE = re.compile(
    r"(?P<skip><!--\s*docs-test:\s*skip\s*-->\s*\n)?"
    r"```python\n(?P<body>.*?)```", re.DOTALL)


def _python_blocks(text: str):
    return [m.group("body") for m in _FENCE.finditer(text)
            if not m.group("skip")]


def test_readme_python_snippets_execute():
    text = README.read_text()
    blocks = _python_blocks(text)
    # the README documents (at least) the sampling and serving quickstarts
    assert len(blocks) >= 2, "README lost its executable quickstart blocks"
    ns: dict = {"__name__": "readme_snippets"}
    for i, block in enumerate(blocks):
        code = compile(block, f"README.md:block[{i}]", "exec")
        exec(code, ns)  # noqa: S102 - executing our own documentation
    # the serving quickstart must actually have produced tokens
    assert ns["tokens"].shape == (16,)
