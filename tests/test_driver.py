"""Async ServeDriver transport contracts: threaded submit with per-request
event streams and futures, asyncio submission, per-request validation-error
delivery, bitwise parity with the synchronous engine, and the HTTP-ish
NDJSON transport in ``repro.launch.serve``."""
import asyncio
import json
import threading
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.serve import make_http_server
from repro.models import transformer as T
from repro.serving.driver import QueueFull, ServeDriver
from repro.serving.engine import DiffusionServeEngine, Request


@pytest.fixture(scope="module")
def diff_setup():
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg


def test_driver_streams_and_matches_sync_engine(diff_setup):
    """Concurrent submits through the driver produce per-request event
    streams with the request's OWN progress (even in a ragged group) and
    final samples bitwise-equal to a synchronous solo serve."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    with ServeDriver(eng) as drv:
        h1 = drv.submit(Request(uid=0, seq_len=8, nfe=3, solver="ddim", seed=1))
        h2 = drv.submit(Request(uid=1, seq_len=8, nfe=6, solver="ddim", seed=2))
        evs = list(h1.events())
        assert [e.k for e in evs] == [1, 2, 3]          # own step count, not
        assert all(e.n_steps == 3 and e.uids == (0,) for e in evs)  # group max
        r1, r2 = h1.result(), h2.result()
    assert (r1.nfe, r2.nfe) == (3, 6)
    sync = DiffusionServeEngine(params, cfg)
    s1 = sync.serve([Request(uid=0, seq_len=8, nfe=3, solver="ddim", seed=1)])
    s2 = sync.serve([Request(uid=1, seq_len=8, nfe=6, solver="ddim", seed=2)])
    np.testing.assert_array_equal(r1.tokens, s1[0].tokens)
    np.testing.assert_array_equal(r2.tokens, s2[0].tokens)


def test_driver_async_submission(diff_setup):
    """submit_async handles support ``async for`` event iteration and
    awaitable results on an asyncio loop while the scheduler thread runs."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)

    async def go(drv):
        h = await drv.submit_async(
            Request(uid=7, seq_len=8, nfe=4, solver="euler", seed=3))
        ks = [ev.k async for ev in h]
        return ks, await h.result()

    with ServeDriver(eng) as drv:
        ks, res = asyncio.run(go(drv))
    assert ks == [1, 2, 3, 4] and res.nfe == 4 and res.tokens.shape == (8,)


def test_driver_validation_error_is_per_request(diff_setup):
    """A bad request fails on ITS handle (the engine's validation exception,
    delivered through the future); concurrent good requests are unaffected
    -- unlike the synchronous serve()'s all-or-nothing batch contract."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    with ServeDriver(eng) as drv:
        good = drv.submit(Request(uid=0, seq_len=8, nfe=3, solver="ddim",
                                  seed=0))
        bad = drv.submit(Request(uid=1, seq_len=8, nfe=3, solver="nope"))
        with pytest.raises(ValueError, match="unknown solver"):
            bad.result(timeout=30)
        assert list(bad.events()) == []               # stream closed, empty
        assert good.result().tokens.shape == (8,)
        with pytest.raises(ValueError, match="eta"):
            drv.submit(Request(uid=2, seq_len=8, nfe=3,
                               solver="ddim_eta")).result(timeout=30)


def test_driver_survives_tick_crash(diff_setup):
    """If a tick raises, the scheduler thread must not die silently: every
    in-flight future fails with the error, the engine queues are reset, and
    the driver keeps serving later submissions."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    real_tick = eng.tick
    boom = {"armed": True}

    def exploding_tick(**kw):
        if boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("device fell over")
        return real_tick(**kw)

    eng.tick = exploding_tick
    with ServeDriver(eng) as drv:
        h = drv.submit(Request(uid=0, seq_len=8, nfe=3, solver="ddim", seed=0))
        with pytest.raises(RuntimeError, match="fell over"):
            h.result(timeout=60)
        assert list(h.events()) == []                 # stream closed
        # driver still alive and serving
        h2 = drv.submit(Request(uid=1, seq_len=8, nfe=3, solver="ddim", seed=0))
        assert h2.result(timeout=120).tokens.shape == (8,)


def test_driver_rejects_duplicate_inflight_uid(diff_setup):
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    with ServeDriver(eng) as drv:
        h = drv.submit(Request(uid=5, seq_len=8, nfe=3, solver="ddim", seed=0))
        with pytest.raises(ValueError, match="already"):
            drv.submit(Request(uid=5, seq_len=8, nfe=3, solver="ddim", seed=1))
        h.result()
        # uid is reusable once the request completed
        drv.submit(Request(uid=5, seq_len=8, nfe=3, solver="ddim",
                           seed=1)).result()


def test_driver_backpressure_sheds_over_max_pending(diff_setup):
    """With max_pending=n the (n+1)-th concurrent submit is shed instantly:
    its OWN handle fails with QueueFull (empty event stream, no driver
    crash), every admitted request completes untouched, and capacity freed
    by completions is reusable."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    with ServeDriver(eng, max_pending=2) as drv:
        h1 = drv.submit(Request(uid=0, seq_len=8, nfe=3, solver="ddim", seed=1))
        h2 = drv.submit(Request(uid=1, seq_len=8, nfe=3, solver="ddim", seed=2))
        shed = drv.submit(Request(uid=2, seq_len=8, nfe=3, solver="ddim",
                                  seed=3))
        assert shed.done()                       # rejected at submit, O(1)
        with pytest.raises(QueueFull, match="max_pending"):
            shed.result(timeout=1)
        assert list(shed.events()) == []         # stream closed, empty
        r1, r2 = h1.result(), h2.result()        # admitted work unaffected
        assert r1.tokens.shape == (8,) and r2.tokens.shape == (8,)
        # completions free capacity; the same uid may come back
        again = drv.submit(Request(uid=2, seq_len=8, nfe=3, solver="ddim",
                                   seed=3))
        assert again.result(timeout=120).tokens.shape == (8,)
        # the shed request's sample is what a non-shed run produces
        sync = DiffusionServeEngine(params, cfg)
        want = sync.serve([Request(uid=2, seq_len=8, nfe=3, solver="ddim",
                                   seed=3)])[0]
        np.testing.assert_array_equal(again.result().tokens, want.tokens)


def test_driver_backpressure_async_path(diff_setup):
    """submit_async sheds identically: the async handle's result() raises
    QueueFull and its async iterator is empty."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)

    async def go(drv):
        h1 = await drv.submit_async(
            Request(uid=0, seq_len=8, nfe=4, solver="ddim", seed=0))
        shed = await drv.submit_async(
            Request(uid=1, seq_len=8, nfe=4, solver="ddim", seed=1))
        assert shed.done()
        evs = [ev async for ev in shed]
        with pytest.raises(QueueFull, match="shed"):
            await shed.result()
        res = await h1.result()
        return evs, res

    with ServeDriver(eng, max_pending=1) as drv:
        evs, res = asyncio.run(go(drv))
    assert evs == [] and res.tokens.shape == (8,)


def test_http_transport_roundtrip(diff_setup):
    """POST /v1/generate against the HTTP-ish transport: non-streaming JSON
    result (bitwise-equal to the driver path) and NDJSON streaming with one
    step line per solver step followed by the result line."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    with ServeDriver(eng) as drv:
        server = make_http_server(drv, 0)           # port 0: OS-assigned
        port = server.server_address[1]
        t = threading.Thread(target=server.serve_forever, daemon=True)
        t.start()
        try:
            url = f"http://127.0.0.1:{port}/v1/generate"
            body = {"seq_len": 8, "nfe": 3, "solver": "ddim", "seed": 1}
            out = json.loads(urllib.request.urlopen(
                urllib.request.Request(url, data=json.dumps(body).encode()),
                timeout=120).read())
            assert out["nfe"] == 3 and len(out["tokens"]) == 8

            lines = urllib.request.urlopen(
                urllib.request.Request(url, data=json.dumps(
                    {**body, "stream": True}).encode()),
                timeout=120).read().decode().strip().split("\n")
            objs = [json.loads(ln) for ln in lines]
            assert [o["event"] for o in objs] == ["step"] * 3 + ["result"]
            assert [o["k"] for o in objs[:-1]] == [1, 2, 3]
            assert objs[-1]["tokens"] == out["tokens"]   # same seed, same sample

            # engine-side validation surfaces as NDJSON error event
            lines = urllib.request.urlopen(
                urllib.request.Request(url, data=json.dumps(
                    {**body, "solver": "nope", "stream": True}).encode()),
                timeout=120).read().decode().strip().split("\n")
            assert json.loads(lines[-1])["event"] == "error"
        finally:
            server.shutdown()
