"""Dry-run machinery unit tests (no 512-device compile here -- just the
host-mesh-independent pieces: HLO collective parsing, model-flops accounting,
XLA scan-cost behavior that motivates depth extrapolation)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.dryrun import collective_bytes, model_flops
from repro.configs.base import get_config


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ar = f32[1024,512]{1,0} all-reduce(f32[1024,512] %x), replica_groups={}
  %ag.1 = bf16[2048]{0} all-gather(bf16[1024] %y), dimensions={0}
  %rs = (f32[256]{0}) reduce-scatter(f32[1024] %z), dimensions={0}
  %a2a = f32[64,64]{1,0} all-to-all(f32[64,64] %w)
  %cp = u32[8]{0} collective-permute(u32[8] %v)
  %notacoll = f32[4]{0} add(f32[4] %a, f32[4] %b)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 2 * 1024 * 512 * 4          # 2x ring
    assert out["all-gather"] == 2048 * 2
    assert out["reduce-scatter"] == 256 * 4
    assert out["all-to-all"] == 64 * 64 * 4
    assert out["collective-permute"] == 8 * 4
    assert out["counts"]["all-reduce"] == 1


def test_collective_parser_ignores_done_ops():
    hlo = """
  %ags = bf16[128]{0} all-gather-start(bf16[64] %x)
  %agd = bf16[128]{0} all-gather-done(bf16[128] %ags)
"""
    out = collective_bytes(hlo)
    assert out["counts"]["all-gather"] == 1
    assert out["all-gather"] == 128 * 2


def test_xla_cost_analysis_counts_scan_body_once():
    """The documented motivation for depth extrapolation: XLA HloCostAnalysis
    does not multiply while-loop body costs by trip count."""
    def one(x, w):
        return x @ w

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def flops(fn):
        ca = jax.jit(fn).lower(x, w).compile().cost_analysis()
        # older jax returns a one-element list of dicts, newer a dict
        return (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]

    assert flops(scanned) < 2 * flops(one)  # NOT 10x: body counted once


def test_model_flops_moe_uses_active_params_only():
    dense = model_flops(get_config("granite_3_8b").with_(objective="ar"), "prefill_32k")
    moe = model_flops(get_config("mixtral_8x7b").with_(objective="ar"), "prefill_32k")
    # mixtral total params ~47B but active ~13B -> flops must reflect active
    n_mix_active = moe / (2.0 * 32 * 32768)
    assert 1.0e10 < n_mix_active < 1.6e10, n_mix_active


def test_model_flops_decode_counts_one_token():
    cfg = get_config("gemma_2b").with_(objective="ar")
    f_dec = model_flops(cfg, "decode_32k")
    f_pre = model_flops(cfg, "prefill_32k")
    # decode tokens = 128, prefill tokens = 32 * 32768
    assert f_pre / f_dec == (32 * 32768) / 128
