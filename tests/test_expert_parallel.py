"""Expert-parallel MoE (shard_map + all_to_all) vs the single-device MoE.

Runs in a SUBPROCESS with 4 fake CPU devices so the main pytest process keeps
its single-device view (the smoke-test constraint). The subprocess asserts
numerical equality against models/layers.moe on identical weights/tokens.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess with fake multi-device CPU mesh

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs.base import get_config
from repro.models import layers as L
from repro.sharding.expert_parallel import moe_expert_parallel

cfg = get_config("mixtral_8x7b").reduced().with_(objective="ar")
cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, num_experts=4, top_k=2,
                                        capacity_factor=100.0))
params = L.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
mesh = jax.make_mesh((4,), ("data",))
b, s, d = 4, 32, cfg.d_model
x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d))

# reference: per-row single-device MoE (cap factor high => no drops)
ref, aux_ref = L.moe(params, cfg, x)

# jax.set_mesh is recent; older jax uses the Mesh context manager directly
_ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
with _ctx:
    out, aux = moe_expert_parallel(params, cfg, x, mesh, axis="data")
err = float(jnp.abs(out - ref).max())
print("max err:", err)
assert err < 2e-4, err
# load-balance stat within tolerance (expert-parallel averages over shards)
assert abs(float(aux["moe_lb"]) - float(aux_ref["moe_lb"])) < 1e-3
print("EXPERT_PARALLEL_OK")
"""


@pytest.mark.parametrize("_", [0])
def test_expert_parallel_matches_single_device(_, tmp_path):
    script = tmp_path / "ep_check.py"
    script.write_text(_SCRIPT)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(os.path.join(
                   os.path.dirname(__file__), "..", "src")))
    res = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=600, env=env)
    assert "EXPERT_PARALLEL_OK" in res.stdout, (res.stdout, res.stderr[-3000:])
