"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

pytestmark = pytest.mark.slow  # Pallas kernel sweeps in interpret mode


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- deis_step
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,d,r", [(8, 16, 1), (300, 130, 3), (256, 128, 4),
                                   (1, 1, 2), (1024, 256, 2)])
def test_deis_step_matches_ref(m, d, r, dtype):
    key = jax.random.PRNGKey(m * 7 + d + r)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (m, d), dtype)
    hist = jax.random.normal(ks[1], (r, m, d), dtype)
    psi = jax.random.uniform(ks[2], (), jnp.float32, 0.5, 1.0)
    coeffs = jax.random.normal(ks[3], (r,), jnp.float32)
    got = ops.deis_step(x, hist, psi, coeffs, interpret=True)
    want = ref.deis_step_ref(x, hist, psi, coeffs)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 400), d=st.integers(1, 300), r=st.integers(1, 4))
def test_deis_step_property(m, d, r):
    key = jax.random.PRNGKey(m * 31 + d * 7 + r)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (m, d))
    hist = jax.random.normal(ks[1], (r, m, d))
    psi = jnp.float32(0.9)
    coeffs = jax.random.normal(ks[3], (r,), jnp.float32)
    got = ops.deis_step(x, hist, psi, coeffs, interpret=True)
    want = ref.deis_step_ref(x, hist, psi, coeffs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,d,causal,window", [
    (2, 64, 4, 4, 32, True, 0),
    (1, 128, 8, 2, 64, True, 0),     # GQA
    (2, 96, 4, 1, 32, True, 0),      # MQA + padded seq (96 % 64)
    (1, 64, 4, 4, 32, False, 0),     # bidirectional (diffusion mode)
    (1, 128, 4, 2, 32, True, 32),    # sliding window
])
def test_flash_attention_matches_ref(b, s, h, kv, d, causal, window, dtype):
    key = jax.random.PRNGKey(b + s + h + d)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              blk_q=32, blk_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_block_shape_independence():
    """Output must not depend on the BlockSpec tiling."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    a = ops.flash_attention(q, k, v, blk_q=128, blk_k=128, interpret=True)
    b = ops.flash_attention(q, k, v, blk_q=32, blk_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------- ssd_scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 2, 16, 8, 16),
    (1, 96, 3, 8, 16, 32),    # padded chunks (96 % 32 == 0; heads odd)
    (1, 50, 2, 16, 8, 16),    # seq not a chunk multiple
    (2, 32, 1, 32, 32, 32),   # single chunk
])
def test_ssd_scan_matches_naive_recurrence(b, s, h, p, n, chunk, dtype):
    key = jax.random.PRNGKey(s + h + p)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    a = jax.random.uniform(ks[1], (b, s, h), jnp.float32, 0.7, 0.999)
    B = jax.random.normal(ks[2], (b, s, n), dtype)
    C = jax.random.normal(ks[3], (b, s, n), dtype)
    y, st_ = ops.ssd_scan(x, a, B, C, chunk=chunk, interpret=True)
    y_ref, st_ref = ref.ssd_scan_ref(x, a, B, C)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(st_ref), **tol)


def test_ssd_chunked_xla_matches_naive():
    """The XLA-path chunked SSD (models/ssm.py) against the recurrence."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    b, s, h, p, n = 2, 64, 2, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    a = jax.random.uniform(ks[1], (b, s, h), jnp.float32, 0.7, 0.999)
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    y, st_ = ssd_chunked(x, a, B, C, chunk=16)
    y_ref, st_ref = ref.ssd_scan_ref(x, a, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(st_ref), rtol=2e-4, atol=2e-4)


def test_ssd_kernel_matches_xla_chunked():
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 4)
    b, s, h, p, n = 1, 128, 2, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    a = jax.random.uniform(ks[1], (b, s, h), jnp.float32, 0.8, 0.999)
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    y1, s1 = ops.ssd_scan(x, a, B, C, chunk=32, interpret=True)
    y2, s2 = ssd_chunked(x, a, B, C, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


def test_flash_attention_cross_attention_offsets_queries():
    """sq != sk: query positions must offset by sk - sq so the LAST query
    aligns with the last key -- a 1-token decode against a 64-entry cache
    attends (causally) to the whole prefix, not just k_pos == 0."""
    key = jax.random.PRNGKey(21)
    ks = jax.random.split(key, 3)
    for sq, sk, window in [(1, 64, 0), (16, 64, 0), (8, 128, 32)]:
        q = jax.random.normal(ks[0], (2, sq, 4, 32))
        k = jax.random.normal(ks[1], (2, sk, 4, 32))
        v = jax.random.normal(ks[2], (2, sk, 4, 32))
        got = ops.flash_attention(q, k, v, causal=True, window=window,
                                  blk_q=32, blk_k=32, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        # the regression this pins: with unshifted query positions a single
        # decode query would mask everything but k_pos == 0
        if sq == 1:
            assert not np.allclose(np.asarray(got), np.asarray(v[:, :1]),
                                   atol=1e-3)


def test_fused_plan_matches_unfused():
    """plan_ab(fused=True) routes Eq. 14 through the Pallas kernel and must
    be numerically identical to the jnp path."""
    from repro.core import VPSDE, get_timesteps, plan_ab, sample
    from repro.diffusion.analytic import GaussianData
    sde = VPSDE()
    d = 8
    g = GaussianData(sde, mean=np.full(d, 1.0), var=np.full(d, 0.3))
    eps = g.eps_fn()
    xT = jax.random.normal(jax.random.PRNGKey(0), (16, d)) * sde.prior_std()
    ts = get_timesteps(sde, 8, "quadratic")
    a = sample(plan_ab(sde, ts, order=3), eps, xT)
    b = sample(plan_ab(sde, ts, order=3, fused=True), eps, xT)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------- compiled-vs-interpret contract
def test_deis_step_default_interpret_is_backend_resolved():
    """The fused kernel must default to the COMPILED Pallas path everywhere a
    compiled lowering exists (TPU: Mosaic, GPU: Triton); only the CPU backend
    -- which has no lowering -- falls back to the Python interpreter. The old
    default of interpret=True meant the "fused" path was slower than the
    un-fused XLA form it claims to beat."""
    from repro.kernels.deis_step import default_interpret
    assert default_interpret() == (jax.default_backend() == "cpu")


def test_deis_step_default_matches_explicit_modes():
    """Whatever mode the backend resolves to, the default-mode kernel output
    must equal the explicit interpret-mode oracle bit-for-bit path-wise (and
    the reference numerically): the compiled path is guarded by numerics, not
    trusted blind."""
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 4)
    m, d, r = 300, 130, 3
    x = jax.random.normal(ks[0], (m, d))
    hist = jax.random.normal(ks[1], (r, m, d))
    psi = jax.random.uniform(ks[2], (), jnp.float32, 0.5, 1.0)
    coeffs = jax.random.normal(ks[3], (r,), jnp.float32)
    got = ops.deis_step(x, hist, psi, coeffs)            # backend default
    oracle = ops.deis_step(x, hist, psi, coeffs, interpret=True)
    want = ref.deis_step_ref(x, hist, psi, coeffs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="perf sanity needs a compiled Pallas lowering "
                           "(no accelerator in this environment)")
def test_deis_step_compiled_is_not_interpreted_speed():
    """On an accelerator the compiled kernel must beat interpret mode by a
    wide margin -- the regression this guards (interpret=True default) made
    the 'fused' path orders of magnitude slower than un-fused XLA."""
    import time
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 2)
    x = jax.random.normal(ks[0], (2048, 512))
    hist = jax.random.normal(ks[1], (3, 2048, 512))
    psi = jnp.float32(0.9)
    coeffs = jnp.array([0.5, 0.3, 0.2], jnp.float32)

    def timed(**kw):
        ops.deis_step(x, hist, psi, coeffs, **kw).block_until_ready()  # warm
        t0 = time.perf_counter()
        for _ in range(5):
            out = ops.deis_step(x, hist, psi, coeffs, **kw)
        out.block_until_ready()
        return time.perf_counter() - t0

    compiled_t = timed()                    # default: compiled on accelerator
    interp_t = timed(interpret=True)
    assert compiled_t * 10 < interp_t, (compiled_t, interp_t)


def test_flash_ssd_default_interpret_is_backend_resolved():
    """flash_attention and ssd_scan are portable Pallas now (no pltpu
    scratch): their defaults must resolve per kernel through the shared
    capability table, exactly like deis_step -- compiled wherever a
    lowering exists, interpreter only on CPU. Unknown kernel names must
    fail loudly (a typo would silently interpret everywhere)."""
    from repro.kernels import runtime
    from repro.kernels.flash_attention import default_interpret as flash_di
    from repro.kernels.ssd_scan import default_interpret as ssd_di
    on_cpu = jax.default_backend() == "cpu"
    assert flash_di() == on_cpu
    assert ssd_di() == on_cpu
    assert runtime.default_interpret("flash_attention") == flash_di()
    assert runtime.default_interpret("ssd_scan") == ssd_di()
    with pytest.raises(ValueError):
        runtime.default_interpret("not_a_kernel")


def test_flash_attention_default_matches_explicit_modes():
    """Default-mode output (backend-resolved) against the forced interpreter
    and the reference: the compiled lowering is guarded by numerics."""
    key = jax.random.PRNGKey(13)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 96, 4, 32))
    k = jax.random.normal(ks[1], (1, 96, 2, 32))
    v = jax.random.normal(ks[2], (1, 96, 2, 32))
    got = ops.flash_attention(q, k, v)                   # backend default
    oracle = ops.flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ssd_scan_default_matches_explicit_modes():
    key = jax.random.PRNGKey(17)
    ks = jax.random.split(key, 4)
    b, s, h, p, n = 1, 96, 2, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    a = jax.random.uniform(ks[1], (b, s, h), jnp.float32, 0.8, 0.999)
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    y, st_ = ops.ssd_scan(x, a, B, C, chunk=32)          # backend default
    y_o, st_o = ops.ssd_scan(x, a, B, C, chunk=32, interpret=True)
    y_r, st_r = ref.ssd_scan_ref(x, a, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_o),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(st_o),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="perf sanity needs a compiled Pallas lowering "
                           "(no accelerator in this environment)")
@pytest.mark.parametrize("kernel", ["flash_attention", "ssd_scan"])
def test_flash_ssd_compiled_is_not_interpreted_speed(kernel):
    """On an accelerator the portable lowerings must beat the interpreter by
    a wide margin -- the regression this guards (TPU-only pltpu shapes +
    blanket off-TPU interpret) ran these kernels 100x slow on GPU."""
    import time
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    if kernel == "flash_attention":
        q = jax.random.normal(ks[0], (1, 512, 8, 64))
        k = jax.random.normal(ks[1], (1, 512, 8, 64))
        v = jax.random.normal(ks[2], (1, 512, 8, 64))

        def call(**kw):
            return ops.flash_attention(q, k, v, **kw)
    else:
        x = jax.random.normal(ks[0], (1, 512, 4, 32))
        a = jax.random.uniform(ks[1], (1, 512, 4), jnp.float32, 0.8, 0.999)
        B = jax.random.normal(ks[2], (1, 512, 32))
        C = jax.random.normal(ks[3], (1, 512, 32))

        def call(**kw):
            return ops.ssd_scan(x, a, B, C, **kw)[0]

    def timed(**kw):
        call(**kw).block_until_ready()                    # warm / compile
        t0 = time.perf_counter()
        for _ in range(5):
            out = call(**kw)
        out.block_until_ready()
        return time.perf_counter() - t0

    compiled_t = timed()
    interp_t = timed(interpret=True)
    assert compiled_t * 10 < interp_t, (kernel, compiled_t, interp_t)


# ------------------------------------------- fused stacked-plan megakernel
def test_fused_ab_step_folds_noise_and_error():
    """The stacked kernel's noise add and error-pair estimate against the
    unfused composition, per row."""
    from repro.kernels.ops import fused_ab_step
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 6)
    R, m, d, r = 3, 70, 33, 3
    x = jax.random.normal(ks[0], (R, m, d))
    hist = jax.random.normal(ks[1], (r, R, m, d))
    psi = jax.random.uniform(ks[2], (R,), jnp.float32, 0.5, 1.0)
    C = jax.random.normal(ks[3], (R, r), jnp.float32)
    s = jax.random.uniform(ks[4], (R,), jnp.float32, 0.0, 0.2)
    noise = jax.random.normal(ks[5], (R, m, d))
    E = jax.random.normal(ks[0], (R, r), jnp.float32) * 0.1
    out, err = fused_ab_step(x, hist, psi, C, s=s, noise=noise,
                             err_coeffs=E, interpret=True)
    want = psi[:, None, None] * x + jnp.einsum("rj,jrmd->rmd", C, hist) \
        + s[:, None, None] * noise
    want_err = jnp.max(jnp.abs(jnp.einsum("rj,jrmd->rmd", E, hist)),
                       axis=(1, 2))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(err), np.asarray(want_err),
                               rtol=1e-5, atol=1e-5)
    # a stacked row must be BITWISE the corresponding solo (R=1) call: the
    # row-block grid axis computes each row's blocks independently
    for i in range(R):
        out_i, err_i = fused_ab_step(
            x[i:i + 1], hist[:, i:i + 1], psi[i:i + 1], C[i:i + 1],
            s=s[i:i + 1], noise=noise[i:i + 1], err_coeffs=E[i:i + 1],
            interpret=True)
        assert np.array_equal(np.asarray(out[i]), np.asarray(out_i[0]))
        assert np.array_equal(np.asarray(err[i]), np.asarray(err_i[0]))


_FUSED_FAMILIES = [("tab2", {}), ("tab3", {}), ("sndeis2", {}),
                   ("seeds2", {}), ("em", {}), ("ddim_eta", {"eta": 0.7})]


@pytest.mark.parametrize("name,kw", _FUSED_FAMILIES)
def test_stacked_fused_bitwise_vs_solo(name, kw):
    """The serving invariant at the sampler level, per family: a row of a
    stacked FUSED group is bitwise identical to the same request solved
    solo through the fused path (deterministic, stochastic s-leaf noise,
    and nu-weighted sndeis history all ride the same kernel), and the
    fused path tracks the unfused XLA path to float32 round-off."""
    import dataclasses as dc

    from repro.core import (VPSDE, get_timesteps, init_state, make_plan,
                            stack_plans, step)
    sde = VPSDE()
    ts = get_timesteps(sde, 6, "quadratic")
    base = make_plan(name, sde, ts, error_estimate=True, **kw)
    assert base.method == "ab"
    fused = dc.replace(base, fused=True)

    def eps_fn(x, t):
        # stacked solves pass per-row t of shape (R,)
        if jnp.ndim(t):
            t = jnp.reshape(t, (-1,) + (1,) * (x.ndim - 1))
        return jnp.tanh(x) * (1.0 + t)

    R, m, d = 3, 4, 16
    keys = jnp.stack([jax.random.PRNGKey(100 + i) for i in range(R)])
    x_rows = [jax.random.normal(jax.random.fold_in(keys[i], 7), (m, d))
              for i in range(R)]

    def solve(plan, rows):
        splan = stack_plans([plan] * len(rows))
        st = init_state(splan, jnp.stack([x_rows[i] for i in rows]),
                        keys[jnp.asarray(rows)])
        for k in range(splan.n_steps):
            st = step(splan, k, st, eps_fn)
        return st

    group = solve(fused, list(range(R)))
    for i in range(R):
        solo = solve(fused, [i])
        assert np.array_equal(np.asarray(group.x[i]), np.asarray(solo.x[0])), \
            f"{name}: stacked row {i} != solo"
        if group.err is not None:
            assert np.array_equal(np.asarray(group.err[i]),
                                  np.asarray(solo.err[0]))
    unfused = solve(base, list(range(R)))
    np.testing.assert_allclose(np.asarray(group.x), np.asarray(unfused.x),
                               rtol=1e-4, atol=1e-4)
    if group.err is not None:
        np.testing.assert_allclose(np.asarray(group.err),
                                   np.asarray(unfused.err),
                                   rtol=1e-3, atol=1e-5)
