"""NLL via the transformed PF-ODE (App. B Q1): converges to EXACT likelihoods
on analytically tractable targets."""
import jax
import numpy as np
import pytest

from repro.core import VPSDE
from repro.core.likelihood import nll_bits_per_dim
from repro.diffusion.analytic import GaussianData, default_gmm

SDE = VPSDE()


def test_nll_exact_gaussian():
    d = 2
    g = GaussianData(SDE, mean=np.full(d, 1.0), var=np.full(d, 0.5))
    x0 = np.array([[1.2, 0.8], [0.5, 1.5], [1.0, 1.0]])
    exact = (0.5 * np.sum((x0 - 1.0) ** 2 / 0.5, -1)
             + 0.5 * d * np.log(2 * np.pi * 0.5)) / d / np.log(2.0)
    est = nll_bits_per_dim(SDE, g.eps_fn(), jax.numpy.asarray(x0), n_steps=32)
    np.testing.assert_allclose(np.asarray(est), exact, rtol=2e-3, atol=2e-3)


def test_nll_gmm_converges_with_steps():
    gmm = default_gmm(SDE, d=2)
    x0 = gmm.sample_data(jax.random.PRNGKey(0), 24)
    exact = float(-gmm.log_prob(x0).mean() / 2 / np.log(2.0))
    errs = []
    for n in (8, 16, 32):
        est = float(nll_bits_per_dim(SDE, gmm.eps_fn(), x0, n_steps=n,
                                     method="kutta3").mean())
        errs.append(abs(est - exact))
    assert errs[2] < errs[0]
    assert errs[2] < 0.02, errs  # ~96 NFE: converged (paper: ~36-48 NFE scale)


def test_nll_hutchinson_close_to_exact_divergence():
    gmm = default_gmm(SDE, d=2)
    x0 = gmm.sample_data(jax.random.PRNGKey(1), 8)
    a = nll_bits_per_dim(SDE, gmm.eps_fn(), x0, n_steps=12, exact_div=True)
    b = nll_bits_per_dim(SDE, gmm.eps_fn(), x0, n_steps=12, exact_div=False,
                         key=jax.random.PRNGKey(2), n_probes=64)
    assert float(np.abs(np.asarray(a) - np.asarray(b)).mean()) < 0.25
