"""Matrix-coefficient DEIS on CLD (paper Sec. 2 generality claim: non-diagonal
F_t/G_t). See core/matrix_sde.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.matrix_sde import (CLD, CLDGaussianOracle, cld_ab_coefficients,
                                   cld_reference, cld_sample)

pytestmark = pytest.mark.slow  # CLD reference solves (~100s module fixture)


@pytest.fixture(scope="module")
def cld():
    return CLD()


@pytest.fixture(scope="module")
def problem(cld):
    orc = CLDGaussianOracle(cld, mean=1.0, var=0.25)
    eps = orc.eps_fn()
    m_t, s_t = orc._moments(1.0)
    z_T = jnp.asarray(m_t) + jax.random.normal(jax.random.PRNGKey(0), (128, 2)) \
        @ jnp.asarray(np.linalg.cholesky(s_t).T)
    ref = cld_reference(cld, eps, z_T, 3000)
    return eps, z_T, ref


def test_transition_matrix_solves_ode(cld):
    """dPsi/dt = beta(t) A Psi(t, s) -- the EI linear term is exact."""
    t, s, h = 0.7, 0.3, 1e-6
    dpsi = (cld.psi(t + h, s) - cld.psi(t - h, s)) / (2 * h)
    resid = np.abs(dpsi - cld.beta(t) * cld.A @ cld.psi(t, s)).max()
    assert resid < 1e-8


def test_transition_matrix_composition(cld):
    """Psi(t, s) = Psi(t, u) Psi(u, s) (semigroup property)."""
    np.testing.assert_allclose(
        cld.psi(0.9, 0.2), cld.psi(0.9, 0.55) @ cld.psi(0.55, 0.2),
        rtol=1e-10, atol=1e-12)


def test_sigma_psd_and_equilibrium(cld):
    for t in (0.01, 0.1, 0.5, 1.0):
        w = np.linalg.eigvalsh(cld.sigma(t))
        assert (w > -1e-12).all(), (t, w)
    np.testing.assert_allclose(cld.sigma(1.0), cld.equilibrium_cov(),
                               atol=0.03)


def test_coefficient_shapes(cld):
    ts = np.linspace(cld.T, cld.t0, 9)
    psi, C = cld_ab_coefficients(cld, ts, order=2)
    assert psi.shape == (8, 2, 2) and C.shape == (8, 3, 2, 2)
    # warmup rows zero-padded
    assert np.allclose(C[0, 1:], 0.0)
    # nonlinear-term coefficients act only through the v channel (N is
    # rank-1 in v): the x-column of C (contribution of eps_x) vanishes
    assert np.abs(C[:, :, :, 0]).max() < 1e-10


@pytest.mark.parametrize("order,min_rate", [(0, 0.8), (1, 1.5)])
def test_matrix_deis_convergence(cld, problem, order, min_rate):
    eps, z_T, ref = problem
    errs = []
    for n in (8, 16, 32):
        ts = np.linspace(cld.T, cld.t0, n + 1)
        z0 = cld_sample(cld, ts, order, eps, z_T)
        errs.append(float(jnp.sqrt(jnp.mean((z0 - ref) ** 2))))
    rates = [np.log2(errs[i] / errs[i + 1]) for i in range(2)]
    assert np.mean(rates) > min_rate, (errs, rates)
    assert errs[-1] < errs[0]


def test_higher_order_beats_order0(cld, problem):
    eps, z_T, ref = problem
    ts = np.linspace(cld.T, cld.t0, 17)
    e0 = float(jnp.sqrt(jnp.mean((cld_sample(cld, ts, 0, eps, z_T) - ref) ** 2)))
    e2 = float(jnp.sqrt(jnp.mean((cld_sample(cld, ts, 2, eps, z_T) - ref) ** 2)))
    assert e2 < e0


def test_x_marginal_recovered(cld, problem):
    """Sampling recovers the data distribution in the x channel."""
    _, _, ref = problem
    x = np.asarray(ref[:, 0])
    assert abs(x.mean() - 1.0) < 0.1
    assert abs(x.var() - 0.25) < 0.12
