"""Unit tests for the observability subsystem (repro.obs).

Pure host-side tests: registry/histogram semantics, the Prometheus and
NDJSON renderers, span nesting, and the BENCH ratchet -- no jax arrays, no
engine. The serving integration (engine counters, driver stats, deadline
eviction accounting) lives in test_serving_fuzz.py / test_driver.py.
"""
import json
import threading

import pytest

from repro.obs import MetricsRegistry, Tracer, NULL_TRACER
from repro.obs import bench
from repro.obs.export import NdjsonExporter, to_ndjson_line, to_prometheus
from repro.obs.metrics import Counter, Gauge, Histogram


# ------------------------------------------------------------------ metrics
def test_counter_inc_and_reset():
    c = Counter("requests_total", "help")
    assert c.value == 0.0
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    c.reset()
    assert c.value == 0.0
    c.reset(7)
    assert c.value == 7.0


def test_gauge_set_and_inc():
    g = Gauge("depth", "help")
    g.set(4)
    g.inc(-1)
    assert g.value == 3.0


def test_histogram_bucket_placement():
    h = Histogram("lat", "help", edges=(1.0, 10.0))
    for v in (0.5, 1.0, 5.0, 100.0):
        h.observe(v)
    # buckets: (-inf, 1], (1, 10], (10, inf) with bisect_left semantics:
    # an observation equal to an edge lands in that edge's bucket
    assert h.counts == [2, 1, 1]
    assert h.cumulative() == [2, 3, 4]
    assert h.count == 4
    assert h.sum == pytest.approx(106.5)
    h.reset()
    assert h.count == 0 and h.sum == 0.0 and h.counts == [0, 0, 0]


def test_histogram_rejects_bad_edges():
    with pytest.raises(ValueError):
        Histogram("h", "help", edges=(1.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", "help", edges=())


def test_registry_idempotent_and_type_checked():
    reg = MetricsRegistry()
    c1 = reg.counter("a_total", help="x")
    c2 = reg.counter("a_total", help="ignored on re-register")
    assert c1 is c2
    with pytest.raises(ValueError):
        reg.gauge("a_total", help="wrong kind under the same name")
    assert "a_total" in reg
    assert reg.get("missing") is None


def test_registry_snapshot_shape():
    reg = MetricsRegistry()
    reg.counter("c_total", help="c").inc(2)
    reg.gauge("g", help="g").set(1.5)
    reg.histogram("h_seconds", help="h", edges=(0.1, 1.0)).observe(0.05)
    snap = reg.snapshot()
    assert snap["c_total"] == 2.0
    assert snap["g"] == 1.5
    assert snap["h_seconds"] == {"edges": [0.1, 1.0], "counts": [1, 0, 0],
                                 "sum": 0.05, "count": 1}
    # a snapshot is a plain-data copy: mutating it must not touch the metric
    snap["h_seconds"]["counts"][0] = 99
    assert reg.get("h_seconds").counts[0] == 1


def test_registry_single_writer_multi_reader():
    """Concurrent reads (scrape threads) during writes never error and the
    final totals are exact -- the registry's documented threading model."""
    reg = MetricsRegistry()
    c = reg.counter("n_total", help="n")
    stop = threading.Event()
    errs = []

    def scrape():
        while not stop.is_set():
            try:
                to_prometheus(reg)
                reg.snapshot()
            except Exception as e:  # noqa: BLE001
                errs.append(e)

    t = threading.Thread(target=scrape)
    t.start()
    for _ in range(20000):
        c.inc()
    stop.set()
    t.join()
    assert not errs
    assert c.value == 20000


# ------------------------------------------------------------------- export
def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("served_total", help="requests served").inc(3)
    reg.gauge("queue_depth", help="pending").set(2)
    h = reg.histogram("solve_seconds", help="solve", edges=(0.5, 2.0))
    h.observe(0.1)
    h.observe(1.0)
    text = to_prometheus(reg)
    assert "# TYPE served_total counter" in text
    assert "served_total 3" in text
    assert "# TYPE queue_depth gauge" in text
    assert 'solve_seconds_bucket{le="0.5"} 1' in text
    assert 'solve_seconds_bucket{le="2"} 2' in text
    assert 'solve_seconds_bucket{le="+Inf"} 2' in text
    assert "solve_seconds_sum 1.1" in text
    assert "solve_seconds_count 2" in text
    assert text.endswith("\n")


def test_ndjson_line_and_exporter(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c_total", help="c").inc()
    doc = json.loads(to_ndjson_line(reg, extra={"run": "t"}))
    assert doc["metrics"]["c_total"] == 1.0
    assert doc["run"] == "t"
    assert doc["ts"] > 0

    path = tmp_path / "metrics.ndjson"
    with NdjsonExporter(str(path)) as ex:
        ex.write(reg)
        reg.get("c_total").inc()
        ex.write(reg)
    lines = path.read_text().splitlines()
    assert [json.loads(l)["metrics"]["c_total"] for l in lines] == [1.0, 2.0]


# -------------------------------------------------------------------- trace
def test_tracer_nested_spans_record_dotted_paths():
    reg = MetricsRegistry()
    tr = Tracer(reg)
    with tr.span("tick"):
        with tr.span("admit"):
            pass
        with tr.span("dispatch"):
            pass
    with tr.span("tick"):
        pass
    assert tr.span_names() == ["tick", "tick.admit", "tick.dispatch"]
    assert reg.get("trace_tick_seconds").count == 2
    assert reg.get("trace_tick.admit_seconds").count == 1


def test_tracer_stack_unwinds_after_exception():
    tr = Tracer(MetricsRegistry())
    with pytest.raises(RuntimeError):
        with tr.span("outer"):
            with tr.span("inner"):
                raise RuntimeError("boom")
    with tr.span("after"):
        pass
    assert "after" in tr.span_names()          # not "outer.after"


def test_null_tracer_records_nothing():
    with NULL_TRACER.span("anything"):
        pass
    assert NULL_TRACER.span_names() == []


# -------------------------------------------------------------------- bench
def _rec(metrics):
    return bench.record("t", metrics, {"quick": True})


def test_bench_metric_validates_direction():
    with pytest.raises(ValueError):
        bench.metric(1.0, direction="sideways")


def test_bench_write_load_roundtrip(tmp_path):
    p = tmp_path / "BENCH_t.json"
    rec = _rec({"m": bench.metric(1.0, unit="us", ratchet=True, tol=0.0)})
    bench.write(str(p), rec)
    assert bench.load(str(p))["metrics"] == rec["metrics"]
    p.write_text('{"schema": "bench.v0"}')
    with pytest.raises(ValueError):
        bench.load(str(p))


def test_bench_self_compare_is_clean():
    rec = _rec({"m": bench.metric(3.0, ratchet=True, tol=0.0),
                "z": bench.metric(0.0, ratchet=True, tol=0.0)})
    assert bench.regressions(bench.compare(rec, rec)) == []


def test_bench_ratchet_directions_and_tol():
    base = _rec({
        "wasted": bench.metric(0.0, direction="lower", ratchet=True, tol=0.0),
        "joined": bench.metric(4.0, direction="higher", ratchet=True, tol=0.0),
        "lat": bench.metric(100.0, direction="lower", ratchet=True, tol=0.1),
        "info": bench.metric(100.0, direction="lower", ratchet=False),
    })
    cur = _rec({
        "wasted": bench.metric(1.0),     # worse (lower is better)
        "joined": bench.metric(3.0),     # worse (higher is better)
        "lat": bench.metric(109.0),      # within 10% tol
        "info": bench.metric(500.0),     # worse but not ratcheted
    })
    by_name = {c.name: c for c in bench.compare(base, cur)}
    assert by_name["wasted"].regressed
    assert by_name["joined"].regressed
    assert not by_name["lat"].regressed
    assert not by_name["info"].regressed
    cur2 = _rec({"lat": bench.metric(111.0)})   # past the 10% tol
    assert bench.compare(base, cur2)[0].regressed


def test_bench_new_and_dropped_metrics_do_not_fail():
    base = _rec({"old": bench.metric(1.0, ratchet=True, tol=0.0)})
    cur = _rec({"new": bench.metric(9.0, ratchet=True, tol=0.0)})
    assert bench.compare(base, cur) == []      # no shared metrics


def test_bench_cli_compare(tmp_path, capsys):
    pb, pc = str(tmp_path / "b.json"), str(tmp_path / "c.json")
    bench.write(pb, _rec({"m": bench.metric(1.0, ratchet=True, tol=0.0)}))
    bench.write(pc, _rec({"m": bench.metric(1.0, ratchet=True, tol=0.0)}))
    assert bench.main(["compare", pb, pc]) == 0
    assert "ratchet clean" in capsys.readouterr().out
    bench.write(pc, _rec({"m": bench.metric(2.0)}))
    assert bench.main(["compare", pb, pc]) == 1
    assert bench.main(["show", pb]) == 0
