"""Equivalence tests for the §Perf optimization levers: the optimized variants
must compute the SAME function as the paper-faithful baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import layers as L
from repro.models import transformer as T
from repro.training.steps import cross_entropy

pytestmark = pytest.mark.slow  # perf-lever equivalence sweeps over full models


@pytest.mark.parametrize("cap_factor", [100.0, 1.0])
def test_moe_gather_matches_einsum_dispatch(cap_factor):
    """gather-dispatch MoE == one-hot-einsum MoE (including capacity drops)."""
    cfg = get_config("mixtral_8x7b").reduced().with_(objective="ar")
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=cap_factor))
    key = jax.random.PRNGKey(0)
    params = L.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    out_e, aux_e = L.moe(params, cfg.with_(moe_dispatch="einsum"), x)
    out_g, aux_g = L.moe(params, cfg.with_(moe_dispatch="gather"), x)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_g),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_e["moe_lb"]), float(aux_g["moe_lb"]),
                               rtol=1e-5)


def test_ce_onehot_matches_gather():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (4, 16, 101))
    targets = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 101)
    cfg_g = get_config("gemma_2b").with_(ce_mode="gather")
    cfg_o = get_config("gemma_2b").with_(ce_mode="onehot")
    a = float(cross_entropy(logits, targets, cfg_g))
    b = float(cross_entropy(logits, targets, cfg_o))
    assert a == pytest.approx(b, rel=1e-6)


def test_ce_onehot_gradients_match():
    cfg_g = get_config("gemma_2b").with_(ce_mode="gather")
    cfg_o = get_config("gemma_2b").with_(ce_mode="onehot")
    logits = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 33))
    targets = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, 33)
    ga = jax.grad(lambda l: cross_entropy(l, targets, cfg_g))(logits)
    gb = jax.grad(lambda l: cross_entropy(l, targets, cfg_o))(logits)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb), rtol=1e-5,
                               atol=1e-7)


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_ssd_chunk_size_invariance(chunk):
    """SSD output must be chunk-size independent (the jamba §Perf lever)."""
    from repro.models.ssm import ssd_chunked
    from repro.kernels import ref
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 4)
    b, s, h, p, n = 1, 64, 2, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    a = jax.random.uniform(ks[1], (b, s, h), jnp.float32, 0.8, 0.999)
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    y, st = ssd_chunked(x, a, B, C, chunk=chunk)
    y_ref, st_ref = ref.ssd_scan_ref(x, a, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4,
                               atol=2e-4)


def test_moe_gather_full_model_forward():
    """gather dispatch drops into the full backbone unchanged."""
    cfg = get_config("mixtral_8x7b").reduced().with_(
        objective="ar", moe_dispatch="gather")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    out = T.forward(params, cfg, tokens=tok, mode="train")
    assert np.isfinite(np.asarray(out["logits"], np.float32)).all()
