"""Property-based plan-algebra suite: randomized solver mixes, NFE grids and
row subsets drive the invariants the serving layer is built on --
``stack_plans`` / ``pad_plan`` / ``take_rows`` / ``inert_row`` /
``join_rows`` keep kept-row prefixes bitwise-exact, join and take round-trip,
and signatures stay stable under every splice.

Runs under real ``hypothesis`` when installed (randomized seeds with
shrinking); on a stock environment it degrades to a fixed battery of seeded
exemplar cases executed with the SAME scenario generator -- not the conftest
stub's skip -- so the properties are always exercised.
"""
import hypothesis
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (VPSDE, get_timesteps, inert_row, init_state,
                        join_rows, join_state_rows, make_plan, pad_plan,
                        stack_plans, take_rows, take_state_rows)

SDE = VPSDE()

# the conftest stub (installed when hypothesis is absent) has no __version__;
# the real package always does
_REAL_HYP = hasattr(hypothesis, "__version__")
_EXEMPLAR_SEEDS = [0, 1, 2, 3, 4, 5, 6, 7, 11, 13, 17, 23]


def fuzz_property(fn):
    """Run ``fn(seed)`` as a hypothesis property over random seeds when the
    real package is installed, else parametrized over exemplar seeds."""
    if _REAL_HYP:
        from hypothesis import given, settings, strategies as st
        return settings(max_examples=25, deadline=None)(
            given(seed=st.integers(min_value=0, max_value=2**31 - 1))(fn))
    return pytest.mark.parametrize("seed", _EXEMPLAR_SEEDS)(fn)


# one entry per signature family: names that stack at a shared grid.
# dpm2m/dpm3m are lambda-basis AB plans, so they land in the SAME executor
# families as the t/rho-basis widths; seeds1 shares the stochastic
# {psi, C, s} layout with em/ddim_eta; scire2/3 are rk tableaus with the
# stage counts of heun/kutta3; sndeis carries the extra ``nu`` key and so
# forms its own (per-width) families.
_FAMILIES = [
    ("ab_w1", ["ddim", "euler", "naive_ei"], 2),
    ("ab_w2", ["tab1", "ipndm1", "dpm2m"], 2),
    ("ab_w3", ["tab2", "ipndm2", "dpm3m"], 2),
    ("ab_w4", ["tab3", "ipndm3"], 3),
    ("stoch", ["em", "ddim_eta", "seeds1"], 2),
    ("stoch_w2", ["seeds2"], 2),
    ("stoch_w3", ["seeds3"], 3),
    ("rk2", ["rho_heun", "rho_midpoint", "dpm2", "scire2"], 2),
    ("rk3", ["rho_kutta3", "scire3"], 2),
    ("rk4", ["rho_rk4"], 2),
    ("pndm", ["pndm"], 5),
    ("sn_w2", ["sndeis1"], 2),
    ("sn_w3", ["sndeis2"], 2),
    ("sn_w4", ["sndeis3"], 3),
]


def _mk(name, n_steps):
    kw = {"eta": 1.0} if name == "ddim_eta" else {}
    return make_plan(name, SDE, get_timesteps(SDE, n_steps, "quadratic"), **kw)


def _scenario(seed):
    """Seed -> (rng, family names, min grid, members): 2-4 random
    same-family plans with random per-member grid sizes."""
    rng = np.random.RandomState(seed % (2**31))
    _, names, lo = _FAMILIES[rng.randint(len(_FAMILIES))]
    k = rng.randint(2, 5)
    members = [_mk(names[rng.randint(len(names))], int(rng.randint(lo, lo + 6)))
               for _ in range(k)]
    return rng, names, lo, members


def _leaves_equal(a, b):
    """Bitwise equality of every dynamic leaf. Deliberately leaf-wise, not
    jax.tree.map: static ``nfe`` is a group-lifetime max that take_rows/
    join_rows preserve while a fresh re-stack of the same rows recomputes
    it, so the treedefs may legitimately differ."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@fuzz_property
def test_stack_rows_are_members_bitwise(seed):
    """Row i of a stack IS member i: every coefficient leaf and ts row is
    the member's array bit-for-bit, nfe is the member max, and stacking is
    signature-stable across member permutations."""
    rng, names, lo, members = _scenario(seed)
    n_max = max(p.n_steps for p in members)
    padded = [pad_plan(p, n_max) for p in members]
    stacked = stack_plans(padded)
    assert stacked.batch == len(members)
    assert stacked.nfe == max(p.nfe for p in members)
    for i, p in enumerate(padded):
        for name, v in p.coeffs.items():
            np.testing.assert_array_equal(np.asarray(stacked.coeffs[name][i]),
                                          np.asarray(v))
        np.testing.assert_array_equal(np.asarray(stacked.ts[i]),
                                      np.asarray(p.ts))
    perm = rng.permutation(len(padded))
    assert stack_plans([padded[i] for i in perm]).signature == stacked.signature


@fuzz_property
def test_pad_plan_prefix_bitwise_and_family(seed):
    """Padding preserves the original steps bit-for-bit, keeps every padded
    leaf finite, never changes family/nfe, and makes same-family plans
    signature-equal (the stackability contract)."""
    rng, names, lo, members = _scenario(seed)
    p = members[0]
    pad = int(rng.randint(1, 5))
    padded = pad_plan(p, p.n_steps + pad)
    assert padded.nfe == p.nfe and padded.family == p.family
    assert padded.n_steps == p.n_steps + pad
    for name, v in p.coeffs.items():
        got = np.asarray(padded.coeffs[name])
        assert np.all(np.isfinite(got))
        lead = v.shape[0] if np.ndim(v) else None
        if lead in (p.n_steps, p.n_steps + 1):   # per-step / per-knot leaf
            np.testing.assert_array_equal(got[:lead], np.asarray(v))
        else:                                    # step-count-independent
            np.testing.assert_array_equal(got, np.asarray(v))
    np.testing.assert_array_equal(np.asarray(padded.ts[:p.n_steps + 1]),
                                  np.asarray(p.ts))
    # two same-family plans padded to one grid have EQUAL signatures
    q = members[-1]
    n = max(p.n_steps, q.n_steps) + 1
    assert pad_plan(p, n).signature == pad_plan(q, n).signature


@fuzz_property
def test_take_rows_gathers_bitwise_and_composes(seed):
    """take_rows is a pure row gather: kept rows are bitwise-unmoved, in the
    requested order, and gathers compose (take of a take == take of the
    composed index)."""
    rng, names, lo, members = _scenario(seed)
    n_max = max(p.n_steps for p in members)
    padded = [pad_plan(p, n_max) for p in members]
    stacked = stack_plans(padded)
    rows = [int(i) for i in
            rng.permutation(len(members))[:rng.randint(1, len(members) + 1)]]
    taken = take_rows(stacked, rows)
    assert taken.signature == stack_plans([padded[i] for i in rows]).signature
    _leaves_equal(taken, stack_plans([padded[i] for i in rows]))
    if len(rows) > 1:
        sub = [int(i) for i in rng.permutation(len(rows))[:1]]
        _leaves_equal(take_rows(taken, sub),
                      take_rows(stacked, [rows[i] for i in sub]))


@fuzz_property
def test_join_rows_prefix_exact_and_roundtrips(seed):
    """join_rows appends padded joiners without touching in-flight rows:
    the leading rows of the joined stack are the original stack bitwise,
    the appended rows are pad_plan(joiner) bitwise, the signature stays in
    the same family at the grown batch, and take(join) round-trips to the
    original stack exactly."""
    rng, names, lo, members = _scenario(seed)
    n_max = max(p.n_steps for p in members)
    stacked = stack_plans([pad_plan(p, n_max) for p in members])
    # joiners: same family, grids at or below the horizon
    joiners = [_mk(names[rng.randint(len(names))], int(rng.randint(lo, n_max + 1)))
               for _ in range(rng.randint(1, 4))]
    joined = join_rows(stacked, joiners)
    R = stacked.batch
    assert joined.batch == R + len(joiners)
    _leaves_equal(take_rows(joined, list(range(R))), stacked)   # round-trip
    for j, p in enumerate(joiners):
        row = take_rows(joined, [R + j])
        _leaves_equal(row, stack_plans([pad_plan(p, n_max)]))
    # executor-cache stability: the joined signature equals a natively
    # stacked batch of the same size
    native = stack_plans([pad_plan(p, n_max)
                          for p in members + joiners])
    assert joined.signature == native.signature


@fuzz_property
def test_join_state_rows_prefix_exact(seed):
    """State splicing keeps veteran leaves bitwise-unmoved in their slots
    and appends the joiners' fresh state; take_state_rows round-trips."""
    rng, names, lo, members = _scenario(seed)
    n_max = max(p.n_steps for p in members)
    stacked = stack_plans([pad_plan(p, n_max) for p in members])
    R, d = stacked.batch, 4
    xT = jnp.asarray(rng.randn(R, d))
    keys = jnp.stack([jax.random.PRNGKey(int(s))
                      for s in rng.randint(0, 1000, R)])
    st = init_state(stacked, xT, keys)
    x_new = jnp.asarray(rng.randn(2, d))
    k_new = jnp.stack([jax.random.PRNGKey(int(s))
                       for s in rng.randint(0, 1000, 2)])
    st_new = init_state(stack_plans([pad_plan(members[0], n_max)] * 2),
                        x_new, k_new)
    joined = join_state_rows(st, st_new)
    np.testing.assert_array_equal(np.asarray(joined.x[:R]), np.asarray(st.x))
    np.testing.assert_array_equal(np.asarray(joined.hist[:, :R]),
                                  np.asarray(st.hist))
    np.testing.assert_array_equal(np.asarray(joined.key[:R]),
                                  np.asarray(st.key))
    np.testing.assert_array_equal(np.asarray(joined.x[R:]),
                                  np.asarray(st_new.x))
    back = take_state_rows(joined, list(range(R)))
    np.testing.assert_array_equal(np.asarray(back.x), np.asarray(st.x))
    np.testing.assert_array_equal(np.asarray(back.key), np.asarray(st.key))


@fuzz_property
def test_inert_row_is_signature_stable_filler(seed):
    """inert_row keeps the member signature (stackable as filler), zeroes
    every weight-like per-step leaf, and reports zero NFE."""
    _, _, _, members = _scenario(seed)
    p = members[0]
    filler = inert_row(p)
    assert filler.signature == p.signature and filler.nfe == 0
    from repro.core.plan import _PER_STEP_COEFFS, _TIME_LIKE
    for name, v in filler.coeffs.items():
        if name in _PER_STEP_COEFFS and name not in _TIME_LIKE:
            assert not np.any(np.asarray(v))
        else:
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(p.coeffs[name]))
    assert stack_plans([p, filler]).batch == 2


# ------------------------------------------- novel coefficient keys (generic)
def _with_novel_keys(p, rng, static_len=None):
    """Attach coefficient leaves under names NO splice primitive has ever
    heard of: a per-step matrix, a per-knot vector, and a static tableau
    (leading axis deliberately != n_steps and != n_steps + 1). The static
    leaf is a family constant, so joiners must carry it at the STACK's
    length, not their own grid's -- pass ``static_len`` for that."""
    import dataclasses
    n = p.n_steps
    extra = {
        "zeta_novel": jnp.asarray(rng.randn(n, 2)),          # per-step
        "knotv_novel": jnp.asarray(rng.randn(n + 1)),        # per-knot
        "tableau_novel": jnp.asarray(rng.randn(static_len or n + 3)),
    }
    return dataclasses.replace(p, coeffs={**p.coeffs, **extra})


@fuzz_property
def test_novel_coeff_key_roundtrips_all_splices(seed):
    """The satellite-3 regression: a plan carrying coefficient keys the
    splice primitives have no registry entry for round-trips through
    pad -> stack -> join -> take bitwise-intact. Padding classifies the
    novel leaves by shape (per-step zero-padded, per-knot edge-replicated,
    static untouched), and every later splice treats the dict generically."""
    rng = np.random.RandomState(seed % (2**31))
    n, pad = int(rng.randint(3, 8)), int(rng.randint(1, 4))
    base = _mk("tab2", n)
    p = _with_novel_keys(base, rng)
    assert p.signature != base.signature        # novel keys are trace-visible

    padded = pad_plan(p, n + pad)
    z = np.asarray(padded.coeffs["zeta_novel"])
    np.testing.assert_array_equal(z[:n], np.asarray(p.coeffs["zeta_novel"]))
    assert not np.any(z[n:])                    # per-step: zero-padded
    kv = np.asarray(padded.coeffs["knotv_novel"])
    np.testing.assert_array_equal(kv[:n + 1],
                                  np.asarray(p.coeffs["knotv_novel"]))
    np.testing.assert_array_equal(kv[n + 1:],
                                  np.full(pad, kv[n]))      # knot: replicated
    np.testing.assert_array_equal(np.asarray(padded.coeffs["tableau_novel"]),
                                  np.asarray(p.coeffs["tableau_novel"]))

    q = _with_novel_keys(_mk("tab2", n), rng)   # same shapes, fresh values
    stacked = stack_plans([padded, pad_plan(q, n + pad)])
    joiner = _with_novel_keys(_mk("tab2", int(rng.randint(3, n + 1))), rng,
                              static_len=n + 3)
    joined = join_rows(stacked, [joiner])
    back = take_rows(joined, [0, 1])
    _leaves_equal(back, stacked)                # pad->stack->join->take
    row2 = take_rows(joined, [2])
    _leaves_equal(row2, stack_plans([pad_plan(joiner, n + pad)]))
    # inert filler zeroes the novel per-step leaf, replicates the rest
    filler = inert_row(p)
    assert filler.signature == p.signature
    assert not np.any(np.asarray(filler.coeffs["zeta_novel"]))
    np.testing.assert_array_equal(np.asarray(filler.coeffs["tableau_novel"]),
                                  np.asarray(p.coeffs["tableau_novel"]))


# ------------------------------------------------- explicit error contracts
def test_join_rows_rejects_incompatible_joiners():
    p6 = make_plan("ddim", SDE, get_timesteps(SDE, 6, "quadratic"))
    p8 = make_plan("ddim", SDE, get_timesteps(SDE, 8, "quadratic"))
    stacked = stack_plans([p6, p6])
    with pytest.raises(ValueError, match="stacked"):
        join_rows(p6, [p6])                       # unstacked base
    with pytest.raises(ValueError, match="unstacked"):
        join_rows(stacked, [stacked])             # stacked joiner
    with pytest.raises(ValueError, match="horizon"):
        join_rows(stacked, [p8])                  # grid exceeds horizon
    with pytest.raises(ValueError, match="family"):
        join_rows(stacked, [make_plan("tab2", SDE,
                                      get_timesteps(SDE, 6, "quadratic"))])
    with pytest.raises(ValueError, match="at least one"):
        join_rows(stacked, [])


def test_join_state_rows_rejects_unstacked_and_mismatched():
    from repro.core import SamplerState
    p = make_plan("tab2", SDE, get_timesteps(SDE, 6, "quadratic"))
    stacked = stack_plans([p, p])
    keys = jnp.stack([jax.random.PRNGKey(s) for s in (1, 2)])
    st = init_state(stacked, jnp.zeros((2, 4)), keys)
    solo = init_state(p, jnp.zeros(4))
    with pytest.raises(ValueError, match="stacked"):
        join_state_rows(st, solo)
    other = init_state(stack_plans([make_plan("ddim", SDE, get_timesteps(
        SDE, 6, "quadratic"))]), jnp.zeros((1, 4)), keys[:1])
    with pytest.raises(ValueError, match="history"):
        join_state_rows(st, other)
