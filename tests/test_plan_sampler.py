"""Plan/step sampler API: legacy-class <-> SolverPlan equivalence for every
solver name, step-wise resume, hooks, jit/vmap composition, and the
explicit-eta factory contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (VPSDE, Hooks, SOLVER_NAMES, get_timesteps, init_state,
                        make_plan, make_solver, plan_ddim, sample, step)
from repro.diffusion.analytic import GaussianData

SDE = VPSDE()
TS = get_timesteps(SDE, 8, "quadratic")
KEY = jax.random.PRNGKey(7)


def _problem(d=4, batch=8):
    g = GaussianData(SDE, mean=np.full(d, 1.5), var=np.full(d, 0.25))
    xT = jax.random.normal(jax.random.PRNGKey(0), (batch, d)) * SDE.prior_std()
    return g.eps_fn(), xT


def _kw(name):
    return {"eta": 1.0} if name == "ddim_eta" else {}


# ------------------------------------------------- legacy <-> plan equivalence
@pytest.mark.parametrize("name", SOLVER_NAMES)
def test_legacy_class_equals_plan_path(name):
    """Every solver name produces identical samples via the legacy class shim
    and the SolverPlan path (deterministic: same arrays; stochastic: same
    arrays under a fixed key)."""
    eps, xT = _problem()
    x_plan = sample(make_plan(name, SDE, TS, **_kw(name)), eps, xT, KEY)
    x_legacy = make_solver(name, SDE, TS, **_kw(name)).sample(eps, xT, KEY)
    np.testing.assert_array_equal(np.asarray(x_plan), np.asarray(x_legacy))


def test_plan_matches_hand_rolled_ddim_eta():
    """Golden pre-redesign formula (Eq. 34): x' = a x + b eps + s xi with the
    per-step key-split pattern -- guards the redesign against drift."""
    eps, xT = _problem()
    eta = 1.0
    ab = np.asarray(SDE.alpha_bar(TS), dtype=np.float64)
    sig2 = (eta ** 2) * (1 - ab[1:]) / (1 - ab[:-1]) * (1 - ab[:-1] / ab[1:])
    sig2 = np.maximum(sig2, 0.0)
    a = np.sqrt(ab[1:] / ab[:-1])
    b = np.sqrt(np.maximum(1 - ab[1:] - sig2, 0.0)) - a * np.sqrt(1 - ab[:-1])
    s = np.sqrt(sig2)
    x, key = xT, KEY
    for k in range(len(TS) - 1):
        key, sub = jax.random.split(key)
        e = eps(x, jnp.asarray(TS[k], x.dtype))
        xi = jax.random.normal(sub, x.shape, x.dtype)
        x = a[k] * x + b[k] * e + s[k] * xi
    got = sample(plan_ddim(SDE, TS, eta=eta), eps, xT, KEY)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-10,
                               atol=1e-10)


def test_plan_matches_hand_rolled_euler():
    """Golden pre-redesign Euler loop: x += dt (f x + g^2/(2 sigma) eps)."""
    eps, xT = _problem()
    f = np.asarray(SDE.f(TS[:-1]), dtype=np.float64)
    coef = 0.5 * np.asarray(SDE.g2(TS[:-1]), np.float64) \
        / np.asarray(SDE.sigma(TS[:-1]), np.float64)
    dt = TS[1:] - TS[:-1]
    x = xT
    for k in range(len(TS) - 1):
        e = eps(x, jnp.asarray(TS[k], x.dtype))
        x = x + dt[k] * (f[k] * x + coef[k] * e)
    got = sample(make_plan("euler", SDE, TS), eps, xT)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-10,
                               atol=1e-10)


# ----------------------------------------------------------- step / resume
@pytest.mark.parametrize("name", ["ddim", "tab3", "rho_heun", "dpm2", "em",
                                  "ddim_eta", "ipndm3", "pndm"])
def test_step_loop_matches_sample(name):
    """sample() == init_state() + step() iterated -- the streaming/resume
    contract serving relies on."""
    eps, xT = _problem()
    plan = make_plan(name, SDE, TS, **_kw(name))
    want = sample(plan, eps, xT, KEY)
    st = init_state(plan, xT, KEY)
    for k in range(plan.n_steps):
        st = step(plan, k, st, eps)
    np.testing.assert_allclose(np.asarray(st.x), np.asarray(want),
                               rtol=1e-10, atol=1e-12)
    assert int(st.k) == plan.n_steps


def test_mid_solve_resume():
    """A solve split across two owners (SamplerState handed over mid-way)
    equals the uninterrupted solve."""
    eps, xT = _problem()
    plan = make_plan("tab2", SDE, TS)
    st = init_state(plan, xT)
    for k in range(plan.n_steps // 2):
        st = step(plan, k, st, eps)
    handoff = jax.tree.map(jnp.array, st)  # serialize/restore stand-in
    for k in range(plan.n_steps // 2, plan.n_steps):
        handoff = step(plan, k, handoff, eps)
    want = sample(plan, eps, xT)
    np.testing.assert_allclose(np.asarray(handoff.x), np.asarray(want),
                               rtol=1e-10, atol=1e-12)


def test_stochastic_plan_requires_key():
    eps, xT = _problem()
    for name in ("em", "ddim_eta"):
        with pytest.raises(ValueError, match="PRNG key"):
            sample(make_plan(name, SDE, TS, **_kw(name)), eps, xT)


# ------------------------------------------------------------------- hooks
def test_trajectory_hook():
    eps, xT = _problem()
    plan = make_plan("tab2", SDE, TS)
    x0, traj = sample(plan, eps, xT, hooks=Hooks(record_trajectory=True))
    assert traj.shape == (plan.n_steps,) + xT.shape
    np.testing.assert_array_equal(np.asarray(traj[-1]), np.asarray(x0))
    np.testing.assert_array_equal(np.asarray(x0),
                                  np.asarray(sample(plan, eps, xT)))


def test_guidance_hook_scales_eps():
    """eps_transform is applied to every network output (identity == no-op;
    a scaling transform must change the result)."""
    eps, xT = _problem()
    plan = make_plan("tab2", SDE, TS)
    base = sample(plan, eps, xT)
    same = sample(plan, eps, xT, hooks=Hooks(eps_transform=lambda x, t, e: e))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(same))
    scaled = sample(plan, eps, xT,
                    hooks=Hooks(eps_transform=lambda x, t, e: 1.5 * e))
    assert not np.allclose(np.asarray(base), np.asarray(scaled))


# ------------------------------------------------------- jit / vmap / cache
def test_jit_shares_executor_across_same_signature_plans():
    """Plans are traced arguments: solver names with equal plan signatures
    (ddim / euler / naive_ei at one NFE) share a single compiled executor."""
    eps, xT = _problem()
    run = jax.jit(lambda p, x: sample(p, eps, x))
    outs = [run(make_plan(n, SDE, TS), xT) for n in ("ddim", "euler", "naive_ei")]
    assert run._cache_size() == 1
    # and they are *different* solvers, not one trace constant-folded
    assert not np.allclose(np.asarray(outs[0]), np.asarray(outs[1]))


def test_vmap_over_batched_state():
    eps, xT = _problem(batch=6)
    plan = make_plan("tab1", SDE, TS)
    got = jax.vmap(lambda x: sample(plan, eps, x))(xT[:, None, :])[:, 0, :]
    want = sample(plan, eps, xT)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-10, atol=1e-12)


# ------------------------------------------------------------- eta contract
def test_make_solver_ddim_eta_requires_explicit_eta():
    """The old factory silently defaulted to eta=1.0 while DDIMSolver
    defaulted to eta=0.0; both factories now require eta explicitly."""
    with pytest.raises(TypeError, match="eta"):
        make_solver("ddim_eta", SDE, TS)
    with pytest.raises(TypeError, match="eta"):
        make_plan("ddim_eta", SDE, TS)


def test_ddim_eta_forwarded():
    eps, xT = _problem()
    det = make_solver("ddim_eta", SDE, TS, eta=0.0).sample(eps, xT)
    ddim = make_solver("ddim", SDE, TS).sample(eps, xT)
    np.testing.assert_allclose(np.asarray(det), np.asarray(ddim),
                               rtol=1e-9, atol=1e-9)
    sto = make_solver("ddim_eta", SDE, TS, eta=1.0)
    assert sto.plan.stochastic and sto.eta == 1.0
    assert not np.allclose(
        np.asarray(sto.sample(eps, xT, KEY)), np.asarray(ddim))


def test_plan_nfe_accounting():
    assert make_plan("pndm", SDE, get_timesteps(SDE, 20, "uniform")).nfe == 29
    assert make_plan("ipndm3", SDE, TS).nfe == 8
    assert make_plan("rho_heun", SDE, TS).nfe == 16
    assert make_plan("rho_rk4", SDE, TS).nfe == 32
