"""Plan/step sampler API: deprecated-factory <-> SolverPlan equivalence for
every solver name, step-wise resume, hooks, jit/vmap composition, and the
explicit-eta factory contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (VPSDE, Hooks, SOLVER_NAMES, get_timesteps, init_state,
                        make_plan, make_solver, plan_ddim, sample, stack_plans,
                        step)
from repro.diffusion.analytic import GaussianData

SDE = VPSDE()
TS = get_timesteps(SDE, 8, "quadratic")
KEY = jax.random.PRNGKey(7)


def _problem(d=4, batch=8):
    g = GaussianData(SDE, mean=np.full(d, 1.5), var=np.full(d, 0.25))
    xT = jax.random.normal(jax.random.PRNGKey(0), (batch, d)) * SDE.prior_std()
    return g.eps_fn(), xT


def _kw(name):
    return {"eta": 1.0} if name == "ddim_eta" else {}


# --------------------------------------------- deprecated factory equivalence
@pytest.mark.parametrize("name", SOLVER_NAMES)
def test_deprecated_make_solver_aliases_make_plan(name):
    """The class shims are gone: ``make_solver`` warns and returns exactly
    the plan ``make_plan`` builds, for every solver name (so stragglers keep
    working, one DeprecationWarning louder)."""
    with pytest.deprecated_call():
        legacy = make_solver(name, SDE, TS, **_kw(name))
    plan = make_plan(name, SDE, TS, **_kw(name))
    assert legacy.signature == plan.signature and legacy.nfe == plan.nfe
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), legacy, plan)
    eps, xT = _problem()
    np.testing.assert_array_equal(
        np.asarray(sample(legacy, eps, xT, KEY)),
        np.asarray(sample(plan, eps, xT, KEY)))


def test_plan_matches_hand_rolled_ddim_eta():
    """Golden pre-redesign formula (Eq. 34): x' = a x + b eps + s xi with the
    per-step key-split pattern -- guards the redesign against drift."""
    eps, xT = _problem()
    eta = 1.0
    ab = np.asarray(SDE.alpha_bar(TS), dtype=np.float64)
    sig2 = (eta ** 2) * (1 - ab[1:]) / (1 - ab[:-1]) * (1 - ab[:-1] / ab[1:])
    sig2 = np.maximum(sig2, 0.0)
    a = np.sqrt(ab[1:] / ab[:-1])
    b = np.sqrt(np.maximum(1 - ab[1:] - sig2, 0.0)) - a * np.sqrt(1 - ab[:-1])
    s = np.sqrt(sig2)
    x, key = xT, KEY
    for k in range(len(TS) - 1):
        key, sub = jax.random.split(key)
        e = eps(x, jnp.asarray(TS[k], x.dtype))
        xi = jax.random.normal(sub, x.shape, x.dtype)
        x = a[k] * x + b[k] * e + s[k] * xi
    got = sample(plan_ddim(SDE, TS, eta=eta), eps, xT, KEY)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-10,
                               atol=1e-10)


def test_plan_matches_hand_rolled_euler():
    """Golden pre-redesign Euler loop: x += dt (f x + g^2/(2 sigma) eps)."""
    eps, xT = _problem()
    f = np.asarray(SDE.f(TS[:-1]), dtype=np.float64)
    coef = 0.5 * np.asarray(SDE.g2(TS[:-1]), np.float64) \
        / np.asarray(SDE.sigma(TS[:-1]), np.float64)
    dt = TS[1:] - TS[:-1]
    x = xT
    for k in range(len(TS) - 1):
        e = eps(x, jnp.asarray(TS[k], x.dtype))
        x = x + dt[k] * (f[k] * x + coef[k] * e)
    got = sample(make_plan("euler", SDE, TS), eps, xT)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-10,
                               atol=1e-10)


# ----------------------------------------------------------- step / resume
@pytest.mark.parametrize("name", ["ddim", "tab3", "rho_heun", "dpm2", "em",
                                  "ddim_eta", "ipndm3", "pndm"])
def test_step_loop_matches_sample(name):
    """sample() == init_state() + step() iterated -- the streaming/resume
    contract serving relies on."""
    eps, xT = _problem()
    plan = make_plan(name, SDE, TS, **_kw(name))
    want = sample(plan, eps, xT, KEY)
    st = init_state(plan, xT, KEY)
    for k in range(plan.n_steps):
        st = step(plan, k, st, eps)
    np.testing.assert_allclose(np.asarray(st.x), np.asarray(want),
                               rtol=1e-10, atol=1e-12)
    assert int(st.k) == plan.n_steps


def test_mid_solve_resume():
    """A solve split across two owners (SamplerState handed over mid-way)
    equals the uninterrupted solve."""
    eps, xT = _problem()
    plan = make_plan("tab2", SDE, TS)
    st = init_state(plan, xT)
    for k in range(plan.n_steps // 2):
        st = step(plan, k, st, eps)
    handoff = jax.tree.map(jnp.array, st)  # serialize/restore stand-in
    for k in range(plan.n_steps // 2, plan.n_steps):
        handoff = step(plan, k, handoff, eps)
    want = sample(plan, eps, xT)
    np.testing.assert_allclose(np.asarray(handoff.x), np.asarray(want),
                               rtol=1e-10, atol=1e-12)


def test_stochastic_plan_requires_key():
    eps, xT = _problem()
    for name in ("em", "ddim_eta"):
        with pytest.raises(ValueError, match="PRNG key"):
            sample(make_plan(name, SDE, TS, **_kw(name)), eps, xT)


# ------------------------------------------------------------------- hooks
def test_trajectory_hook():
    eps, xT = _problem()
    plan = make_plan("tab2", SDE, TS)
    x0, traj = sample(plan, eps, xT, hooks=Hooks(record_trajectory=True))
    assert traj.shape == (plan.n_steps,) + xT.shape
    np.testing.assert_array_equal(np.asarray(traj[-1]), np.asarray(x0))
    np.testing.assert_array_equal(np.asarray(x0),
                                  np.asarray(sample(plan, eps, xT)))


def test_guidance_hook_scales_eps():
    """eps_transform is applied to every network output (identity == no-op;
    a scaling transform must change the result)."""
    eps, xT = _problem()
    plan = make_plan("tab2", SDE, TS)
    base = sample(plan, eps, xT)
    same = sample(plan, eps, xT, hooks=Hooks(eps_transform=lambda x, t, e: e))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(same))
    scaled = sample(plan, eps, xT,
                    hooks=Hooks(eps_transform=lambda x, t, e: 1.5 * e))
    assert not np.allclose(np.asarray(base), np.asarray(scaled))


# ------------------------------------------------------- jit / vmap / cache
def test_jit_shares_executor_across_same_signature_plans():
    """Plans are traced arguments: solver names with equal plan signatures
    (ddim / euler / naive_ei at one NFE) share a single compiled executor."""
    eps, xT = _problem()
    run = jax.jit(lambda p, x: sample(p, eps, x))
    outs = [run(make_plan(n, SDE, TS), xT) for n in ("ddim", "euler", "naive_ei")]
    assert run._cache_size() == 1
    # and they are *different* solvers, not one trace constant-folded
    assert not np.allclose(np.asarray(outs[0]), np.asarray(outs[1]))


def test_vmap_over_batched_state():
    eps, xT = _problem(batch=6)
    plan = make_plan("tab1", SDE, TS)
    got = jax.vmap(lambda x: sample(plan, eps, x))(xT[:, None, :])[:, 0, :]
    want = sample(plan, eps, xT)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-10, atol=1e-12)


# ------------------------------------------------------------- eta contract
def test_make_solver_ddim_eta_requires_explicit_eta():
    """The old factory silently defaulted to eta=1.0 while the class shim
    defaulted to eta=0.0; both factories now require eta explicitly."""
    with pytest.raises(TypeError, match="eta"), pytest.deprecated_call():
        make_solver("ddim_eta", SDE, TS)
    with pytest.raises(TypeError, match="eta"):
        make_plan("ddim_eta", SDE, TS)


def test_ddim_eta_forwarded():
    eps, xT = _problem()
    det = sample(make_plan("ddim_eta", SDE, TS, eta=0.0), eps, xT)
    ddim = sample(make_plan("ddim", SDE, TS), eps, xT)
    np.testing.assert_allclose(np.asarray(det), np.asarray(ddim),
                               rtol=1e-9, atol=1e-9)
    sto = make_plan("ddim_eta", SDE, TS, eta=1.0)
    assert sto.stochastic
    assert not np.allclose(
        np.asarray(sample(sto, eps, xT, KEY)), np.asarray(ddim))


# ------------------------------------------------------------ stacked plans
def _per_request_keys(seeds):
    return jnp.stack([jax.random.PRNGKey(s) for s in seeds])


@pytest.mark.parametrize("names,keys", [
    (("ddim", "euler", "naive_ei"), None),       # mixed deterministic names
    (("tab2", "tab2", "tab2"), None),            # homogeneous multistep
    (("rho_rk4", "rho_rk4", "rho_rk4"), None),   # homogeneous RK
    (("rho_heun", "dpm2", "rho_midpoint"), None),  # mixed RK tableaus
    (("em", "ddim_eta", "em"), (11, 12, 13)),    # mixed stochastic
])
def test_stacked_rows_bitwise_match_single_request_solves(names, keys):
    """Row i of a stacked solve is bit-identical to solving request i alone
    (same key chain, same draws) -- the per-request reproducibility contract
    streamed serving is built on.

    One carve-out: mixed RK tableaus give each row *different* stage times,
    and CPU SIMD transcendentals (exp in sde.mu) may differ by 1 ulp between
    packet lanes and the scalar remainder path depending on vector length.
    That case asserts <= 1 ulp instead of bit equality."""
    eps, xT = _problem(batch=len(names))
    plans = [make_plan(n, SDE, TS, **_kw(n)) for n in names]
    kstack = _per_request_keys(keys) if keys else None
    out = sample(stack_plans(plans), eps, xT, kstack)
    mixed_t_rows = plans[0].method == "rk" and len(
        {np.asarray(p.coeffs["stage_t"]).tobytes() for p in plans}) > 1
    for i, p in enumerate(plans):
        solo = sample(stack_plans([p]), eps, xT[i:i + 1],
                      kstack[i:i + 1] if keys else None)
        if mixed_t_rows:
            np.testing.assert_allclose(np.asarray(solo[0]), np.asarray(out[i]),
                                       rtol=1e-15, atol=0)
        else:
            np.testing.assert_array_equal(np.asarray(solo[0]),
                                          np.asarray(out[i]))


def test_interleaved_stacked_step_groups_match_one_shot_sample():
    """The streaming schedule: two groups admitted at different step
    boundaries, steps interleaved, equals one-shot sample() per request --
    including stochastic plans with distinct per-request seeds."""
    eps, xT = _problem(batch=4)
    ga = stack_plans([make_plan("tab2", SDE, TS)] * 2)            # group A
    gb = stack_plans([make_plan("em", SDE, TS),                    # group B
                      make_plan("ddim_eta", SDE, TS, eta=1.0)])
    kb = _per_request_keys([21, 22])
    sa = init_state(ga, xT[:2])
    for k in range(2):                       # A runs 2 steps before B arrives
        sa = step(ga, k, sa, eps)
    sb = init_state(gb, xT[2:], kb)
    ka = 2
    for k in range(gb.n_steps):              # interleave A and B per tick
        if ka < ga.n_steps:
            sa = step(ga, ka, sa, eps)
            ka += 1
        sb = step(gb, k, sb, eps)
    want_a = sample(ga, eps, xT[:2])
    want_b = sample(gb, eps, xT[2:], kb)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(want_a),
                               rtol=1e-12, atol=1e-14)
    np.testing.assert_allclose(np.asarray(sb.x), np.asarray(want_b),
                               rtol=1e-12, atol=1e-14)
    # distinct seeds produced distinct stochastic samples
    assert not np.allclose(np.asarray(sb.x[0]), np.asarray(sb.x[1]))


def test_stacked_step_is_single_trace_over_k():
    """One jitted step serves every step index of a stacked plan (k is a
    traced argument), including pndm's structural warmup/tail split."""
    eps, xT = _problem(batch=2)
    for name in ("tab2", "rho_heun", "pndm"):
        ts = get_timesteps(SDE, 8, "uniform") if name == "pndm" else TS
        plan = stack_plans([make_plan(name, SDE, ts)] * 2)
        run = jax.jit(lambda k, st, p=plan: step(p, k, st, eps))
        st = init_state(plan, xT)
        for k in range(plan.n_steps):
            st = run(jnp.int32(k), st)
        assert run._cache_size() == 1
        np.testing.assert_allclose(np.asarray(st.x),
                                   np.asarray(sample(plan, eps, xT)),
                                   rtol=1e-10, atol=1e-12)


def test_stack_plans_rejects_mismatched_signatures():
    with pytest.raises(ValueError, match="signature"):
        stack_plans([make_plan("ddim", SDE, TS), make_plan("tab2", SDE, TS)])
    with pytest.raises(ValueError, match="stack"):
        stack_plans([stack_plans([make_plan("ddim", SDE, TS)])])


# ---------------------------------------- ragged plans: pad / family / gather
@pytest.mark.parametrize("name", ["ddim", "tab3", "rho_rk4", "pndm", "em"])
def test_pad_plan_prefix_bitwise_and_family(name):
    """Padding preserves the original steps bit-for-bit (the padded solve's
    first n steps equal the unpadded solve), keeps padded steps finite, and
    makes same-family/different-NFE plans stackable. rho_rk4 guards the
    registry: its per-stage ``b`` weights share a length with a 4-step grid
    and must NOT be treated as a step axis."""
    from repro.core import pad_plan
    n1, n2 = (5, 9) if name == "pndm" else (4, 8)
    p1 = make_plan(name, SDE, get_timesteps(SDE, n1, "quadratic"), **_kw(name))
    p2 = make_plan(name, SDE, get_timesteps(SDE, n2, "quadratic"), **_kw(name))
    assert p1.family == p2.family
    assert p1.signature != p2.signature
    padded = pad_plan(p1, p2.n_steps)
    assert padded.signature == p2.signature and padded.nfe == p1.nfe
    eps, xT = _problem(batch=2)
    st_a, st_b = init_state(p1, xT, KEY), init_state(padded, xT, KEY)
    for k in range(p1.n_steps):
        st_a = step(p1, k, st_a, eps)
        st_b = step(padded, k, st_b, eps)
    np.testing.assert_array_equal(np.asarray(st_a.x), np.asarray(st_b.x))
    for k in range(p1.n_steps, padded.n_steps):   # inert region stays finite
        st_b = step(padded, k, st_b, eps)
    assert np.all(np.isfinite(np.asarray(st_b.x)))
    stacked = stack_plans([padded, p2])
    assert stacked.batch == 2 and stacked.nfe == max(p1.nfe, p2.nfe)


def test_take_rows_and_state_rows_bit_exact_mid_solve():
    """Mid-solve compaction primitive: gathering rows of a stacked stochastic
    solve and continuing yields bitwise the same per-request samples as the
    uncompacted stack (key chains move whole)."""
    from repro.core import take_rows, take_state_rows
    eps, _ = _problem(d=4)
    plans = [make_plan("em", SDE, TS)] * 3
    keys = jnp.stack([jax.random.PRNGKey(s) for s in (3, 4, 5)])
    xT = jax.vmap(lambda kk: jax.random.normal(kk, (4,)))(
        jnp.stack([jax.random.PRNGKey(s) for s in (13, 14, 15)]))
    full = stack_plans(plans)
    st_full = init_state(full, xT, keys)
    st_cmp = init_state(full, xT, keys)
    cmp_plan = full
    for k in range(full.n_steps):
        st_full = step(full, k, st_full, eps)
        st_cmp = step(cmp_plan, k, st_cmp, eps)
        if k == 2:                                  # compact away row 1
            cmp_plan = take_rows(cmp_plan, [0, 2])
            st_cmp = take_state_rows(st_cmp, [0, 2])
    np.testing.assert_array_equal(np.asarray(st_full.x)[[0, 2]],
                                  np.asarray(st_cmp.x))
    with pytest.raises(ValueError, match="stacked"):
        take_rows(make_plan("ddim", SDE, TS), [0])
    with pytest.raises(ValueError, match="non-empty"):
        take_state_rows(st_cmp, [])


def test_stacked_state_validation():
    plan = stack_plans([make_plan("em", SDE, TS)] * 2)
    eps, xT = _problem(batch=2)
    with pytest.raises(ValueError, match="PRNG key"):
        init_state(plan, xT)                       # stochastic needs keys
    with pytest.raises(ValueError, match="per-request keys"):
        init_state(plan, xT, jax.random.PRNGKey(0))  # one key is not enough
    with pytest.raises(ValueError, match="leading axis"):
        init_state(plan, xT[:1], _per_request_keys([1, 2]))


def test_plan_nfe_accounting():
    assert make_plan("pndm", SDE, get_timesteps(SDE, 20, "uniform")).nfe == 29
    assert make_plan("ipndm3", SDE, TS).nfe == 8
    assert make_plan("rho_heun", SDE, TS).nfe == 16
    assert make_plan("rho_rk4", SDE, TS).nfe == 32


# ------------------------------------------------------- step-level tracing
@pytest.mark.parametrize("name", ["tab3", "pndm"])
def test_sample_with_tracer_matches_untraced(name):
    """``sample(..., tracer=...)`` swaps the fori_loop for eagerly
    dispatched steps and records one ``sample.step`` span per step -- and
    the result matches the untraced solve (bitwise for pndm, which eagerly
    unrolls either way; to solver tolerance for ab/rk, where XLA may fuse
    the loop body differently)."""
    from repro.obs import MetricsRegistry, Tracer

    eps, xT = _problem()
    plan = make_plan(name, SDE, TS)
    want = sample(plan, eps, xT)
    tr = Tracer(MetricsRegistry())
    got = sample(plan, eps, xT, tracer=tr)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    if name.startswith("pndm"):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert tr.span_names() == ["sample.step"]
    assert tr.registry.get("trace_sample.step_seconds").count == plan.n_steps


# ------------------------------------- transfer guard: dynamic twin of RL001
# One solver per stepper family (ab/rk/stochastic/pndm): the full solve must
# run without a single implicit device<->host transfer -- the runtime check
# backing the static host-sync lint (see docs/static_analysis.md).
GUARD_NAMES = ["tab3", "rho_heun", "em", "pndm"]


@pytest.fixture(scope="module")
def guard_prep():
    """Everything host-touching happens here, OUTSIDE the guard: plan
    construction (numpy coefficient tables), input materialization, jit
    wrapping, device-resident int32 step indices, and the unguarded
    reference solve. Tests then run only jitted device work under the
    guard and fetch results with an explicit ``jax.device_get``."""
    eps, xT = _problem()
    out = {}
    for name in GUARD_NAMES:
        ts = TS if name != "pndm" else get_timesteps(SDE, 8, "uniform")
        plan = make_plan(name, SDE, ts)
        jit_step = jax.jit(lambda k, st, _p=plan: step(_p, k, st, eps))
        jit_sample = jax.jit(lambda _p=plan: sample(_p, eps, xT, KEY))
        out[name] = {
            "state0": init_state(plan, xT, KEY),
            "jit_step": jit_step,
            "jit_sample": jit_sample,
            "ks": [jnp.int32(k) for k in range(plan.n_steps)],
            "want": np.asarray(sample(plan, eps, xT, KEY)),
        }
    return out


@pytest.mark.parametrize("name", GUARD_NAMES)
def test_sample_no_implicit_transfers(name, guard_prep, no_implicit_transfers):
    """A jitted full solve compiles and runs entirely on-device: any stray
    ``float()``/``bool()``/np coercion in the plan/sampler path would raise
    under the guard (including during the cold compile, which happens
    inside it)."""
    p = guard_prep[name]
    got = jax.device_get(p["jit_sample"]())
    np.testing.assert_allclose(got, p["want"], rtol=1e-7, atol=1e-9)


@pytest.mark.parametrize("name", GUARD_NAMES)
def test_step_loop_no_implicit_transfers(name, guard_prep,
                                         no_implicit_transfers):
    """The serving-style loop -- one jitted ``step`` per k with k as a
    device int32 -- stays transfer-free across every step of every stepper
    family, and lands on the same x_0 as the fused solve."""
    p = guard_prep[name]
    st = p["state0"]
    for k in p["ks"]:
        st = p["jit_step"](k, st)
    got = jax.device_get(st.x)
    np.testing.assert_allclose(got, p["want"], rtol=1e-7, atol=1e-9)
