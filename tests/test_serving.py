"""Serving engines: AR generation against step-by-step reference; DEIS
diffusion service batching semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serving.engine import ARServeEngine, DiffusionServeEngine, Request


def test_ar_engine_matches_manual_greedy():
    cfg = get_config("gemma_2b").reduced().with_(objective="ar")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.array([1, 2, 3, 4, 5, 6, 7, 8])
    eng = ARServeEngine(params, cfg, max_len=32)
    res = eng.serve([Request(uid=0, prompt=prompt, max_new_tokens=6)])
    got = res[0].tokens

    # manual greedy via repeated FULL forwards (no cache) -- ground truth
    toks = list(prompt)
    want = []
    for _ in range(6):
        out = T.forward(params, cfg, tokens=jnp.asarray(toks)[None], mode="train")
        nxt = int(jnp.argmax(out["logits"][0, -1]))
        want.append(nxt)
        toks.append(nxt)
    np.testing.assert_array_equal(got, np.array(want))


def test_diffusion_engine_batches_same_shape_requests():
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = DiffusionServeEngine(params, cfg)
    reqs = [Request(uid=i, seq_len=16, nfe=4, solver="tab1", seed=0)
            for i in range(3)] + [Request(uid=9, seq_len=24, nfe=4,
                                          solver="tab1", seed=0)]
    res = eng.serve(reqs)
    assert len(res) == 4
    by_uid = {r.uid: r for r in res}
    assert by_uid[0].tokens.shape == (16,)
    assert by_uid[9].tokens.shape == (24,)
    # same-group requests were one batched solve -> identical latency records
    assert by_uid[0].latency_s == by_uid[1].latency_s == by_uid[2].latency_s
    # deterministic given seed: same compiled fn, same key
    res2 = eng.serve(reqs)
    np.testing.assert_array_equal(res2[0].tokens, res[0].tokens)


def test_diffusion_engine_nfe_accounting():
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = DiffusionServeEngine(params, cfg)
    res = eng.serve([Request(uid=0, seq_len=8, nfe=6, solver="ddim")])
    assert res[0].nfe == 6


def test_diffusion_engine_shares_executor_across_solver_names():
    """Mixed-solver request groups: the compile cache is keyed on
    (plan signature, batch, seq_len), so solver names whose plans share a
    signature reuse ONE jitted executor instead of one per solver name."""
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = DiffusionServeEngine(params, cfg)

    # 3 solver names, same plan signature (affine/"ab", C shape (N,1)), same
    # (nfe, batch, seq_len) -> 3 groups, 1 executor
    reqs = []
    for j, solver in enumerate(["ddim", "euler", "naive_ei"]):
        reqs += [Request(uid=10 * j + i, seq_len=16, nfe=4, solver=solver,
                         seed=0) for i in range(2)]
    res = eng.serve(reqs)
    assert len(res) == 6
    assert len(eng._plans) == 3
    assert len(eng._compiled) == 1

    # different coefficient shape (tab2: C is (N,3)) -> one more executor
    eng.serve([Request(uid=90 + i, seq_len=16, nfe=4, solver="tab2", seed=0)
               for i in range(2)])
    assert len(eng._compiled) == 2

    # stochastic pair (em / ddim_eta) shares one stochastic-affine executor
    eng.serve([Request(uid=100 + i, seq_len=16, nfe=4, solver="em", seed=0)
               for i in range(2)])
    eng.serve([Request(uid=110 + i, seq_len=16, nfe=4, solver="ddim_eta",
                       eta=1.0, seed=0) for i in range(2)])
    assert len(eng._compiled) == 3

    # results differ across solvers (shared executor, different plan data)
    by_uid = {r.uid: r for r in res}
    assert by_uid[0].tokens.shape == (16,)

    # the explicit-eta contract reaches the serving layer too
    import pytest
    with pytest.raises(ValueError, match="eta"):
        eng.serve([Request(uid=120, seq_len=16, nfe=4, solver="ddim_eta")])
