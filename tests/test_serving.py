"""Serving engines: AR generation against step-by-step reference; DEIS
diffusion service streaming continuous-batching semantics (per-request
reproducibility, step-boundary admission, compile/solve time split, NFE
budget accounting, per-step callbacks)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.diffusion import lm as DLM
from repro.models import transformer as T
from repro.serving.engine import ARServeEngine, DiffusionServeEngine, Request


def test_ar_engine_matches_manual_greedy():
    cfg = get_config("gemma_2b").reduced().with_(objective="ar")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.array([1, 2, 3, 4, 5, 6, 7, 8])
    eng = ARServeEngine(params, cfg, max_len=32)
    res = eng.serve([Request(uid=0, prompt=prompt, max_new_tokens=6)])
    # one monotonic clock domain (perf_counter): latency can never go
    # negative, even across a wall-clock step
    assert res[0].latency_s >= 0.0
    got = res[0].tokens

    # manual greedy via repeated FULL forwards (no cache) -- ground truth
    toks = list(prompt)
    want = []
    for _ in range(6):
        out = T.forward(params, cfg, tokens=jnp.asarray(toks)[None], mode="train")
        nxt = int(jnp.argmax(out["logits"][0, -1]))
        want.append(nxt)
        toks.append(nxt)
    np.testing.assert_array_equal(got, np.array(want))


def test_diffusion_engine_batches_same_shape_requests():
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = DiffusionServeEngine(params, cfg)
    reqs = [Request(uid=i, seq_len=16, nfe=4, solver="tab1", seed=0)
            for i in range(3)] + [Request(uid=9, seq_len=24, nfe=4,
                                          solver="tab1", seed=0)]
    res = eng.serve(reqs)
    assert len(res) == 4
    assert all(r.latency_s >= 0.0 and r.compile_s >= 0.0 for r in res)
    by_uid = {r.uid: r for r in res}
    assert by_uid[0].tokens.shape == (16,)
    assert by_uid[9].tokens.shape == (24,)
    # same-group requests were one batched solve -> identical latency records
    assert by_uid[0].latency_s == by_uid[1].latency_s == by_uid[2].latency_s
    # deterministic given seed: same compiled fn, same key
    res2 = eng.serve(reqs)
    np.testing.assert_array_equal(res2[0].tokens, res[0].tokens)


def test_diffusion_engine_nfe_accounting():
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = DiffusionServeEngine(params, cfg)
    res = eng.serve([Request(uid=0, seq_len=8, nfe=6, solver="ddim")])
    assert res[0].nfe == 6


def test_diffusion_engine_shares_executor_across_solver_names():
    """Mixed-solver request groups: the compile cache is keyed on
    (plan signature, batch, seq_len), so solver names whose plans share a
    signature reuse ONE jitted executor instead of one per solver name."""
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = DiffusionServeEngine(params, cfg)

    # 3 solver names, same plan signature (affine/"ab", C shape (N,1)), same
    # (nfe, batch, seq_len) -> 3 groups, 1 executor
    reqs = []
    for j, solver in enumerate(["ddim", "euler", "naive_ei"]):
        reqs += [Request(uid=10 * j + i, seq_len=16, nfe=4, solver=solver,
                         seed=0) for i in range(2)]
    res = eng.serve(reqs)
    assert len(res) == 6
    assert len(eng._plans) == 3
    assert len(eng._compiled) == 1

    # different coefficient shape (tab2: C is (N,3)) -> one more executor
    eng.serve([Request(uid=90 + i, seq_len=16, nfe=4, solver="tab2", seed=0)
               for i in range(2)])
    assert len(eng._compiled) == 2

    # stochastic pair (em / ddim_eta) shares one stochastic-affine executor
    eng.serve([Request(uid=100 + i, seq_len=16, nfe=4, solver="em", seed=0)
               for i in range(2)])
    eng.serve([Request(uid=110 + i, seq_len=16, nfe=4, solver="ddim_eta",
                       eta=1.0, seed=0) for i in range(2)])
    assert len(eng._compiled) == 3

    # results differ across solvers (shared executor, different plan data)
    by_uid = {r.uid: r for r in res}
    assert by_uid[0].tokens.shape == (16,)

    # the explicit-eta contract reaches the serving layer too
    with pytest.raises(ValueError, match="eta"):
        eng.serve([Request(uid=120, seq_len=16, nfe=4, solver="ddim_eta")])


# ------------------------------------------------ streaming engine contracts
@pytest.fixture(scope="module")
def diff_setup():
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg


def test_streaming_interleaved_groups_match_one_shot(diff_setup):
    """Two groups admitted at different step boundaries, steps interleaved,
    must produce per-request outputs identical to one-shot solves -- both the
    engine's own solo serve and the pure ``sample_tokens_stream`` reference.
    Covers stochastic plans (em, ddim_eta) with distinct per-request seeds."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    # group A: deterministic multistep, two distinct seeds
    eng.submit(Request(uid=0, seq_len=16, nfe=6, solver="tab2", seed=3))
    eng.submit(Request(uid=1, seq_len=16, nfe=6, solver="tab2", seed=4))
    out = eng.tick() + eng.tick()        # A is 2 steps in ...
    # ... when group B (stochastic, mixed names: em + ddim_eta stack) arrives
    eng.submit(Request(uid=2, seq_len=16, nfe=6, solver="em", seed=5))
    eng.submit(Request(uid=3, seq_len=16, nfe=6, solver="ddim_eta", eta=1.0,
                       seed=6))
    while eng.busy:
        out += eng.tick()
    got = {r.uid: r.tokens for r in out}
    assert len(got) == 4

    # one-shot reference 1: the same engine serving each request alone
    solo_eng = DiffusionServeEngine(params, cfg)
    spec = {0: ("tab2", 3, None), 1: ("tab2", 4, None), 2: ("em", 5, None),
            3: ("ddim_eta", 6, 1.0)}
    for uid, (solver, seed, eta) in spec.items():
        solo = solo_eng.serve([Request(uid=uid, seq_len=16, nfe=6,
                                       solver=solver, seed=seed, eta=eta)])
        np.testing.assert_array_equal(solo[0].tokens, got[uid])

    # one-shot reference 2: the pure per-request-keyed sample() path
    from repro.core.plan import stack_plans
    sde = eng.sde
    for uid, (solver, seed, eta) in spec.items():
        plan = eng._plan(solver, 6, eta)
        toks, _ = DLM.sample_tokens_stream(
            params, cfg, stack_plans([plan]), DLM.request_keys([seed]),
            seq_len=16, prior_std=sde.prior_std())
        np.testing.assert_array_equal(np.asarray(toks)[0], got[uid])


def test_per_request_seeds_honored(diff_setup):
    """Distinct seeds in one batched group => distinct samples; equal seeds
    => identical samples, reproducible across serve calls (the old engine
    keyed the whole group on reqs[0].seed)."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    reqs = [Request(uid=i, seq_len=16, nfe=4, solver="ddim_eta", eta=1.0,
                    seed=s) for i, s in enumerate([7, 8, 7])]
    by = {r.uid: r.tokens for r in eng.serve(reqs)}
    np.testing.assert_array_equal(by[0], by[2])      # same seed, same sample
    assert not np.array_equal(by[0], by[1])          # distinct seed differs
    by2 = {r.uid: r.tokens for r in eng.serve(reqs)}  # reproducible
    for uid in by:
        np.testing.assert_array_equal(by[uid], by2[uid])


def test_rk_nfe_budget_honored(diff_setup):
    """RK-family requests must not blow their NFE budget: a nfe=10 rho_rk4
    request runs a 2-interval grid (8 evals), not a 10-interval one (40)."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    res = eng.serve([Request(uid=0, seq_len=8, nfe=10, solver="rho_rk4",
                             seed=0)])
    assert res[0].nfe == 8 and res[0].nfe <= 10
    res = eng.serve([Request(uid=1, seq_len=8, nfe=6, solver="rho_heun",
                             seed=0)])
    assert res[0].nfe == 6
    # pndm's 3x3 extra warmup evals count against the budget too
    res = eng.serve([Request(uid=2, seq_len=8, nfe=20, solver="pndm",
                             seed=0)])
    assert res[0].nfe == 20


def test_latency_excludes_compile(diff_setup):
    """First serve on a cold cache reports compile_s > 0 separately from
    latency_s; a warm-cache serve reports compile_s == 0 (the old engine
    folded trace cost into every request's latency)."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    req = [Request(uid=0, seq_len=12, nfe=3, solver="tab1", seed=0)]
    cold = eng.serve(req)[0]
    assert cold.compile_s > 0 and cold.latency_s > 0
    warm = eng.serve(req)[0]
    assert warm.compile_s == 0.0 and warm.latency_s > 0
    # compile dominates trace-heavy first calls; solve time must not include it
    assert warm.latency_s < cold.latency_s + cold.compile_s


def test_on_step_callback_streams_progress(diff_setup):
    """on_step fires once per group per solver step with progress counters;
    stream_decode=True additionally carries per-step partial decodes of the
    stacked group."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    events = []
    reqs = [Request(uid=i, seq_len=8, nfe=4, solver="ddim", seed=i)
            for i in range(2)]
    res = eng.serve(reqs, on_step=events.append, stream_decode=True)
    assert [e.k for e in events] == [1, 2, 3, 4]
    assert all(e.uids == (0, 1) and e.n_steps == 4 for e in events)
    assert all(e.tokens.shape == (2, 8) for e in events)
    # the last streamed partial decode IS the final result
    final = {r.uid: r.tokens for r in res}
    np.testing.assert_array_equal(events[-1].tokens[0], final[0])
    np.testing.assert_array_equal(events[-1].tokens[1], final[1])


def test_invalid_request_cannot_strand_queued_work(diff_setup):
    """Validation happens at submit time and serve() is all-or-nothing: a bad
    request in a batch leaves the queue empty, and a later serve call sees
    only its own requests."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    good = Request(uid=0, seq_len=8, nfe=3, solver="ddim", seed=0)
    with pytest.raises(ValueError, match="eta"):
        eng.serve([good, Request(uid=1, seq_len=8, nfe=3, solver="ddim_eta")])
    assert not eng.busy                       # uid=0 was rolled back, not lost
    with pytest.raises(ValueError, match="unknown solver"):
        eng.submit(Request(uid=2, seq_len=8, nfe=3, solver="nope"))
    res = eng.serve([Request(uid=3, seq_len=8, nfe=3, solver="ddim", seed=0)])
    assert [r.uid for r in res] == [3]        # no stale strays drained in


# ---------------------------------------- ragged groups / compaction / EDF
def _ragged_reqs():
    """One family bucket (ddim/euler, C width 1) with three NFE budgets."""
    return [Request(uid=0, seq_len=16, nfe=3, solver="ddim", seed=1),
            Request(uid=1, seq_len=16, nfe=6, solver="ddim", seed=2),
            Request(uid=2, seq_len=16, nfe=6, solver="euler", seed=3),
            Request(uid=3, seq_len=16, nfe=9, solver="ddim", seed=4)]


def test_ragged_compaction_bitwise_vs_solo(diff_setup):
    """A ragged-NFE group with compaction produces bitwise-identical samples
    per request vs. solo solves: padding leaves each row's true steps
    untouched, and compaction row-gathers coefficients, state and key chains
    whole. The shrinking batches land in the shared executor cache."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg, compaction=True)
    res = {r.uid: r for r in eng.serve(_ragged_reqs())}
    assert len(res) == 4
    assert eng.wasted_row_steps == 0           # compaction: no dead-row steps
    # one ragged group of 4 compacted to 3 (after nfe=3 retires) then 1
    assert sorted(k[1] for k in eng._compiled) == [1, 3, 4]
    # true per-request NFE survives padding (group plan was padded to 9)
    assert {u: r.nfe for u, r in res.items()} == {0: 3, 1: 6, 2: 6, 3: 9}
    # ragged rows finish EARLY: the nfe=3 row's Result is emitted mid-group
    assert res[0].latency_s < res[3].latency_s
    solo = DiffusionServeEngine(params, cfg)
    for q in _ragged_reqs():
        s = solo.serve([q])[0]
        np.testing.assert_array_equal(s.tokens, res[q.uid].tokens)


def test_compaction_reduces_wasted_row_steps(diff_setup):
    """Without compaction a ragged group burns one step per retired row per
    tick (here: 6 + 3 + 3 = 12); with compaction, zero. Samples must be
    bitwise identical either way."""
    params, cfg = diff_setup
    off = DiffusionServeEngine(params, cfg, compaction=False)
    res_off = {r.uid: r.tokens for r in off.serve(_ragged_reqs())}
    assert off.wasted_row_steps == 12
    on = DiffusionServeEngine(params, cfg, compaction=True)
    res_on = {r.uid: r.tokens for r in on.serve(_ragged_reqs())}
    assert on.wasted_row_steps == 0
    for uid in res_off:
        np.testing.assert_array_equal(res_off[uid], res_on[uid])


def test_deadline_request_preempts_older_work(diff_setup):
    """EDF under a throttled scheduler (steps_per_tick=1): a deadline-tight
    request submitted AFTER an in-flight best-effort group is stepped ahead
    of it every tick until it completes -- and the old work still drains."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg, steps_per_tick=1,
                               aging_ticks=1000)
    events = []
    eng.submit(Request(uid=0, seq_len=16, nfe=6, solver="tab1", seed=0))
    done = eng.tick(on_step=events.append)          # A in flight, k=1
    eng.submit(Request(uid=1, seq_len=16, nfe=3, solver="tab1", seed=1,
                       deadline_s=0.05))
    while eng.busy:
        done += eng.tick(on_step=events.append)
    # B (deadline) takes every tick from admission until it finishes
    assert [e.uids[0] for e in events] == [0, 1, 1, 1, 0, 0, 0, 0, 0]
    assert [r.uid for r in done] == [1, 0]          # B finishes first


def test_compaction_recomputes_group_urgency(diff_setup):
    """When the urgent row of a ragged group retires, the surviving
    best-effort rows must NOT inherit its priority/deadline: a mid-priority
    newcomer preempts the compacted leftovers (no priority inversion).
    join=False isolates the compaction path -- with joins on, the newcomer
    would be spliced into the leftover group instead (covered by the join
    tests below)."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg, steps_per_tick=1,
                               aging_ticks=1000, join=False)
    eng.submit(Request(uid=0, seq_len=16, nfe=3, solver="ddim", seed=0,
                       priority=2, deadline_s=0.05))
    eng.submit(Request(uid=1, seq_len=16, nfe=9, solver="ddim", seed=1))
    events, done = [], []
    for _ in range(3):                    # urgent row finishes and retires
        done += eng.tick(on_step=events.append)
    assert [r.uid for r in done] == [0]
    eng.submit(Request(uid=2, seq_len=16, nfe=3, solver="ddim", seed=2,
                       priority=1))
    while eng.busy:
        done += eng.tick(on_step=events.append)
    # the newcomer ran ahead of the leftover best-effort row every tick
    assert [e.uids for e in events[3:6]] == [(2,), (2,), (2,)]
    assert [r.uid for r in done] == [0, 2, 1]


def test_engine_rejects_invalid_shapes_at_submit(diff_setup):
    """seq_len/nfe validation happens at submit, before anything can reach a
    scheduler tick (a negative seq_len used to blow up inside tick() -- fatal
    for a driver thread)."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    with pytest.raises(ValueError, match="seq_len"):
        eng.submit(Request(uid=0, seq_len=-1, nfe=3, solver="ddim"))
    with pytest.raises(ValueError, match="nfe"):
        eng.submit(Request(uid=0, seq_len=8, nfe=0, solver="ddim"))
    assert not eng.busy


def test_starvation_aging_boosts_skipped_group(diff_setup):
    """A best-effort group facing persistent higher-priority work is boosted
    one effective-priority level per aging_ticks skipped ticks, so it makes
    progress BEFORE the high-priority stream drains (no starvation)."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg, steps_per_tick=1, aging_ticks=2)
    events = []
    eng.submit(Request(uid=0, seq_len=16, nfe=4, solver="tab1", seed=0))
    done = eng.tick(on_step=events.append)          # A steps once
    eng.submit(Request(uid=1, seq_len=16, nfe=8, solver="tab1", seed=1,
                       priority=2))
    while eng.busy:
        done += eng.tick(on_step=events.append)
    order = [e.uids[0] for e in events]
    b_span = (order.index(1), len(order) - 1 - order[::-1].index(1))
    # aging got A at least one step strictly inside B's run ...
    assert 0 in order[b_span[0]:b_span[1]], order
    # ... while B (higher priority) still finished first
    assert [r.uid for r in done] == [1, 0]


# ----------------------------------------- continuous admission (joins)
def test_join_at_compaction_boundary_bitwise_vs_solo(diff_setup):
    """A request pending when a group's row retires is spliced INTO the
    surviving group (continuous admission) instead of forming a fresh one,
    and every sample -- veteran and joiner -- is bitwise-identical to its
    solo serve. The joiner's steps count from its own admission tick: its
    nfe is its own plan's, and its latency excludes the group's pre-join
    solve time."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    eng.submit(Request(uid=0, seq_len=16, nfe=3, solver="ddim", seed=1))
    eng.submit(Request(uid=1, seq_len=16, nfe=9, solver="ddim", seed=2))
    out = []
    for _ in range(3):
        out += eng.tick()                    # uid=0 retires at tick 3
    eng.submit(Request(uid=2, seq_len=16, nfe=4, solver="euler", seed=3))
    ticks_before = eng.ticks
    while eng.busy:
        out += eng.tick()
    got = {r.uid: r for r in out}
    assert eng.joined_requests == 1          # uid=2 joined, no fresh group
    assert eng.wasted_row_steps == 0
    # joiner accounting runs on ITS OWN steps, not the group's age
    assert got[2].nfe == 4
    assert got[2].latency_s < got[1].latency_s   # 4 post-join steps < 9
    assert got[2].queue_wait_s >= 0.0
    # the joiner finished 4 ticks after admission (k0=3 -> done at g.k=7)
    assert eng.ticks - ticks_before == 6     # group drains at uid1's k=9
    solo = DiffusionServeEngine(params, cfg)
    for q in [Request(uid=0, seq_len=16, nfe=3, solver="ddim", seed=1),
              Request(uid=1, seq_len=16, nfe=9, solver="ddim", seed=2),
              Request(uid=2, seq_len=16, nfe=4, solver="euler", seed=3)]:
        np.testing.assert_array_equal(solo.serve([q])[0].tokens,
                                      got[q.uid].tokens)


def test_join_keeps_executor_set_fixed(diff_setup):
    """The never-drain/never-recompile contract: replaying the same
    join-heavy workload on a warm engine adds no executors and charges no
    compile time."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)

    def run():
        eng.submit(Request(uid=0, seq_len=16, nfe=3, solver="ddim", seed=1))
        eng.submit(Request(uid=1, seq_len=16, nfe=8, solver="ddim", seed=2))
        out = []
        for _ in range(3):
            out += eng.tick()
        eng.submit(Request(uid=2, seq_len=16, nfe=5, solver="ddim", seed=3))
        while eng.busy:
            out += eng.tick()
        return out

    run()
    n = eng.num_executors
    warm = run()
    assert eng.num_executors == n
    assert all(r.compile_s == 0.0 for r in warm)


def test_join_respects_max_group(diff_setup):
    """Joins never grow a group past max_group: surplus candidates form a
    fresh group under the same urgency order."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg, max_group=2)
    eng.submit(Request(uid=0, seq_len=16, nfe=3, solver="ddim", seed=1))
    eng.submit(Request(uid=1, seq_len=16, nfe=6, solver="ddim", seed=2))
    out = []
    for _ in range(3):
        out += eng.tick()                    # uid=0 retired: one free slot
    eng.submit(Request(uid=2, seq_len=16, nfe=4, solver="ddim", seed=3))
    eng.submit(Request(uid=3, seq_len=16, nfe=4, solver="ddim", seed=4))
    while eng.busy:
        out += eng.tick()
    assert eng.joined_requests == 1          # one slot -> one joiner
    assert len(out) == 4
    solo = DiffusionServeEngine(params, cfg)
    for q in [Request(uid=2, seq_len=16, nfe=4, solver="ddim", seed=3),
              Request(uid=3, seq_len=16, nfe=4, solver="ddim", seed=4)]:
        np.testing.assert_array_equal(
            solo.serve([q])[0].tokens,
            {r.uid: r for r in out}[q.uid].tokens)


def test_joiner_longer_than_horizon_forms_fresh_group(diff_setup):
    """A pending request whose grid exceeds the group's horizon cannot join
    (extending the grid would change the signature); it forms a fresh group
    and still solves correctly."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    eng.submit(Request(uid=0, seq_len=16, nfe=3, solver="ddim", seed=1))
    eng.submit(Request(uid=1, seq_len=16, nfe=6, solver="ddim", seed=2))
    out = []
    for _ in range(3):
        out += eng.tick()
    eng.submit(Request(uid=2, seq_len=16, nfe=9, solver="ddim", seed=3))
    while eng.busy:
        out += eng.tick()
    assert eng.joined_requests == 0
    solo = DiffusionServeEngine(params, cfg)
    np.testing.assert_array_equal(
        solo.serve([Request(uid=2, seq_len=16, nfe=9, solver="ddim",
                            seed=3)])[0].tokens,
        {r.uid: r for r in out}[2].tokens)


def test_joined_request_streams_own_progress(diff_setup):
    """StepEvent.row_k counts a joiner's steps from ITS admission tick, so
    per-request progress streams correctly for rows joined mid-flight."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg)
    events = []
    eng.submit(Request(uid=0, seq_len=16, nfe=3, solver="ddim", seed=1))
    eng.submit(Request(uid=1, seq_len=16, nfe=7, solver="ddim", seed=2))
    for _ in range(3):
        eng.tick(on_step=events.append)
    eng.submit(Request(uid=2, seq_len=16, nfe=4, solver="ddim", seed=3))
    while eng.busy:
        eng.tick(on_step=events.append)
    assert eng.joined_requests == 1
    prog = [dict(zip(e.uids, e.row_k)) for e in events]
    assert [p.get(2) for p in prog] == [None, None, None, 1, 2, 3, 4]
    assert [p[1] for p in prog] == [1, 2, 3, 4, 5, 6, 7]   # veteran unmoved


def test_seq_len_buckets_share_executor(diff_setup):
    """seq_len_buckets rounds requests up to bucket edges: seq 12 and 16
    solve at one (signature, batch, 16) executor, results are masked back
    to each request's true length, and samples stay reproducible."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg, seq_len_buckets=(16,))
    reqs = [Request(uid=0, seq_len=12, nfe=4, solver="ddim", seed=1),
            Request(uid=1, seq_len=16, nfe=4, solver="ddim", seed=2)]
    res = {r.uid: r for r in eng.serve(list(reqs))}
    assert res[0].tokens.shape == (12,)
    assert res[1].tokens.shape == (16,)
    # ONE executor: both lengths bucket to 16 and stack into one group
    assert {(k[1], k[2]) for k in eng._compiled} == {(2, 16)}
    # reproducible; solo reference shares the bucket config
    solo = DiffusionServeEngine(params, cfg, seq_len_buckets=(16,))
    for q in reqs:
        np.testing.assert_array_equal(solo.serve([q])[0].tokens,
                                      res[q.uid].tokens)
    # beyond the last edge: exact length, no bucketing
    big = eng.serve([Request(uid=2, seq_len=24, nfe=4, solver="ddim",
                             seed=3)])[0]
    assert big.tokens.shape == (24,)
    with pytest.raises(ValueError, match="seq_len_buckets"):
        DiffusionServeEngine(params, cfg, seq_len_buckets=(16, 8))


def test_seq_len_bucket_content_matches_unbucketed(diff_setup):
    """Bucket-independence for deterministic solvers: the prior is drawn at
    the request's TRUE length and padded tail keys are masked out of every
    attention call, so a seq-12 request solved in a 16-bucket returns the
    SAME tokens as the same request solved unbucketed at its exact length
    (the PR-5 caveat this kills: sample content used to depend on which
    bucket a request landed in)."""
    params, cfg = diff_setup
    req = Request(uid=0, seq_len=12, nfe=4, solver="ddim", seed=9)
    bucketed = DiffusionServeEngine(params, cfg, seq_len_buckets=(16,))
    exact = DiffusionServeEngine(params, cfg)
    got = bucketed.serve([dataclasses.replace(req)])[0]
    want = exact.serve([dataclasses.replace(req)])[0]
    assert got.tokens.shape == want.tokens.shape == (12,)
    np.testing.assert_array_equal(got.tokens, want.tokens)


def test_seq_len_bucket_stream_decode_masks_tail(diff_setup):
    """stream_decode under bucketing: group events carry bucket-length rows
    plus row_seq_lens so consumers (the driver) can mask the tail; final
    Results are already masked."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg, seq_len_buckets=(16,))
    events = []
    res = eng.serve([Request(uid=0, seq_len=10, nfe=3, solver="ddim",
                             seed=1)],
                    on_step=events.append, stream_decode=True)
    assert all(e.tokens.shape == (1, 16) for e in events)
    assert all(e.row_seq_lens == (10,) for e in events)
    assert res[0].tokens.shape == (10,)
    np.testing.assert_array_equal(events[-1].tokens[0][:10], res[0].tokens)


def test_admission_splits_oversized_buckets(diff_setup):
    """Buckets larger than max_group split into multiple stacked groups, each
    with its own executor cache entry keyed on its batch size."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg, max_group=2)
    reqs = [Request(uid=i, seq_len=8, nfe=3, solver="ddim", seed=i)
            for i in range(5)]
    res = eng.serve(reqs)
    assert len(res) == 5
    assert {k[1] for k in eng._compiled} == {2, 1}   # two of 2, one of 1
