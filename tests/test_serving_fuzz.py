"""Deterministic fuzz simulation of the serving scheduler.

Seeded random workloads -- arrival ticks, priorities, deadlines, NFE
budgets, seq_lens and solver names -- are driven through
``DiffusionServeEngine`` with joins on and off (and, in the slow tier, on
an 8-device host mesh), asserting the three invariants the scheduler is
contractually not allowed to trade away:

* **bitwise-vs-solo (same controller)**: every Result equals the same
  request served alone on an identically-configured engine -- scheduling
  (grouping, joining, compaction, priorities, timing) never changes WHAT a
  request computes. "Identically configured" includes the early-exit
  controller: an engine with a RetirePolicy is compared against a solo
  engine under the SAME policy, and must retire each row at the identical
  own-step with the identical sample and NFE (the retire decision is a pure
  per-row function of the row's own error estimate, and the estimate's Linf
  reduction is batch-composition independent);
* **zero warm recompiles**: replaying the workload on the warm engine adds
  no executors and charges no compile time (the fixed-executor-set
  contract continuous admission exists to protect);
* **starvation-freedom / liveness**: the simulation drains within a
  bounded number of ticks and every submitted request gets a Result.

Arrivals are keyed to tick indices and deadlines are coarsely separated,
so the schedule -- group composition, join decisions, executor set -- is
deterministic across replays; that is what makes the recompile assertion
meaningful.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serving.engine import DiffusionServeEngine, Request

# every solver generation in one stream: classic ab deterministic/stochastic
# and wide-ab families, plus one representative of each next-gen family
# (DPM-Solver multistep, SEEDS exponential SDE, SciRE rk, score-normalized
# DEIS with its extra nu coefficient key)
_SOLVERS = ["ddim", "euler", "em", "ddim_eta", "tab2",
            "dpm2m", "seeds1", "scire2", "sndeis2"]
_MAX_TICKS = 2000


@pytest.fixture(scope="module")
def diff_setup():
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg


def _gen_workload(fuzz_seed: int, n: int):
    """Seed -> [(arrival_tick, Request)]: random solver/NFE/seq_len/seed/
    priority/deadline mixes. Deadlines come from a VERY coarse grid (60s
    apart, far beyond any run's wall-clock spread) so the EDF order -- and
    therefore group composition and the executor set -- is identical
    between the cold pass and the warm replay, which is what makes the
    zero-recompile assertion deterministic."""
    rng = np.random.RandomState(fuzz_seed)
    out = []
    for uid in range(n):
        solver = _SOLVERS[rng.randint(len(_SOLVERS))]
        out.append((int(rng.randint(0, 8)), Request(
            uid=uid,
            seq_len=int(rng.randint(5, 9)),          # buckets to 8
            nfe=int(rng.randint(3, 9)),
            solver=solver,
            eta=1.0 if solver == "ddim_eta" else None,
            seed=int(rng.randint(0, 100)),
            priority=int(rng.randint(0, 3)),
            deadline_s=float(rng.choice([30.0, 90.0]))
            if rng.rand() < 0.4 else None)))
    return out


def _drive(eng, workload):
    """Submit at arrival ticks, tick until drained; assert liveness."""
    pending = sorted(workload, key=lambda a: a[0])
    i, t, results = 0, 0, []
    while i < len(pending) or eng.busy:
        while i < len(pending) and pending[i][0] <= t:
            eng.submit(pending[i][1])
            i += 1
        results += eng.tick()
        t += 1
        assert t < _MAX_TICKS, "scheduler failed to drain (starvation?)"
    return {r.uid: r for r in results}


def _make_engine(params, cfg, join):
    return DiffusionServeEngine(params, cfg, steps_per_tick=2, aging_ticks=3,
                                max_group=3, join=join, seq_len_buckets=(8,))


@pytest.fixture(scope="module")
def solo_engine(diff_setup):
    """One solo-reference engine reused across cases (same bucket config as
    the fuzzed engines; its (sig, 1, seq) executors warm up once)."""
    params, cfg = diff_setup
    return DiffusionServeEngine(params, cfg, seq_len_buckets=(8,))


@pytest.mark.parametrize("join", [True, False], ids=["joins_on", "joins_off"])
@pytest.mark.parametrize("fuzz_seed", [0, 1])
def test_fuzz_traffic_bitwise_vs_solo_and_warm_cache(diff_setup, solo_engine,
                                                     join, fuzz_seed):
    params, cfg = diff_setup
    workload = _gen_workload(fuzz_seed, n=10)
    eng = _make_engine(params, cfg, join)
    got = _drive(eng, workload)
    assert len(got) == len(workload)                 # every request answered
    assert eng.wasted_row_steps == 0                 # compaction/join cover all
    if not join:
        assert eng.joined_requests == 0

    # bitwise-vs-solo: content is a pure function of
    # (solver, nfe, eta, seed, bucketed seq_len)
    for _, req in workload:
        solo = solo_engine.serve([Request(
            uid=req.uid, seq_len=req.seq_len, nfe=req.nfe, solver=req.solver,
            eta=req.eta, seed=req.seed)])[0]
        np.testing.assert_array_equal(solo.tokens, got[req.uid].tokens)
        assert got[req.uid].nfe == solo.nfe          # true per-request NFE
        assert got[req.uid].latency_s >= 0.0
        assert got[req.uid].queue_wait_s >= 0.0

    # zero warm recompiles: the replayed schedule is deterministic, so the
    # executor set is closed after one pass
    n_exec = eng.num_executors
    warm = _drive(eng, workload)
    assert eng.num_executors == n_exec, "warm fuzz replay recompiled"
    assert all(r.compile_s == 0.0 for r in warm.values())
    for uid in got:                                  # replay is bit-stable
        np.testing.assert_array_equal(warm[uid].tokens, got[uid].tokens)


def test_fuzz_joins_admit_into_inflight_groups(diff_setup):
    """Sanity on the fuzz harness itself: with joins on, a continuous
    ragged stream (a short+long pair arriving every tick, so retired rows
    open slots while later pairs are still pending) actually exercises the
    join path -- otherwise the joins_on/joins_off cases above would be
    testing the same engine."""
    params, cfg = diff_setup
    nfes = [3, 9, 6, 9, 3, 6, 9, 3, 6, 3]
    workload = [(i // 2, Request(uid=i, seq_len=8, nfe=nfes[i],
                                 solver="ddim", seed=i))
                for i in range(10)]
    eng = _make_engine(params, cfg, join=True)
    got = _drive(eng, workload)
    assert len(got) == 10
    assert eng.joined_requests > 0


# ----------------------------------- early-exit serving (controller fuzz)
_EE_POLICY = dict(tol=1.0, min_k=2)   # loose: reduced-config estimates sit
                                      # well under 1.0 a step or two in


@pytest.fixture(scope="module")
def solo_engine_ee(diff_setup):
    """Solo reference under the SAME RetirePolicy as the fuzzed engines --
    the early-exit bitwise invariant is vs-solo-with-same-controller."""
    from repro.core.adaptive import RetirePolicy
    params, cfg = diff_setup
    return DiffusionServeEngine(params, cfg, seq_len_buckets=(8,),
                                retire=RetirePolicy(**_EE_POLICY))


@pytest.mark.parametrize("join", [True, False], ids=["joins_on", "joins_off"])
@pytest.mark.parametrize("fuzz_seed", [0, 1])
def test_fuzz_early_exit_bitwise_vs_solo_same_controller(diff_setup,
                                                         solo_engine_ee,
                                                         join, fuzz_seed):
    """Early-exit fuzz: under a shared RetirePolicy, grouping/joining/
    compaction never change WHEN a row retires or WHAT it returns -- every
    Result (early-exit or natural) is bitwise the solo engine's, with the
    same nfe and early_exit flag; saved NFEs are conserved into the
    registry; and the estimate-carrying executors stay warm-cache closed."""
    from repro.core.adaptive import RetirePolicy
    params, cfg = diff_setup
    # guarantee embedded-pair traffic: the random mix plus a tab2 burst
    workload = _gen_workload(fuzz_seed, n=8)
    workload += [(i, Request(uid=100 + i, seq_len=8, nfe=6 + i % 3,
                             solver="tab2", seed=i)) for i in range(4)]
    eng = DiffusionServeEngine(params, cfg, steps_per_tick=2, aging_ticks=3,
                               max_group=3, join=join, seq_len_buckets=(8,),
                               retire=RetirePolicy(**_EE_POLICY))
    got = _drive(eng, workload)
    assert len(got) == len(workload)
    assert eng.wasted_row_steps == 0

    m = eng.metrics
    n_early = sum(r.early_exit for r in got.values())
    assert n_early > 0                            # the dimension is exercised
    assert m.get("serve_early_exit_total").value == n_early
    # early exits COMPLETE (conservation: they deliver a sample)
    assert m.get("serve_completed_total").value == len(workload)
    saved = m.get("serve_saved_nfe_total").value
    assert saved == sum(
        req.nfe - got[req.uid].nfe for _, req in workload
        if got[req.uid].early_exit)
    assert saved > 0

    for _, req in workload:
        res = got[req.uid]
        solo = solo_engine_ee.serve([Request(
            uid=req.uid, seq_len=req.seq_len, nfe=req.nfe, solver=req.solver,
            eta=req.eta, seed=req.seed)])[0]
        np.testing.assert_array_equal(solo.tokens, res.tokens)
        assert (solo.early_exit, solo.nfe) == (res.early_exit, res.nfe)
        # final_err is only ULP-stable across DIFFERENT executables (solo is
        # batch-1, the fuzz group batch-N: the E-combination fuses
        # differently per executable while tokens/nfe/exit-step stay exact)
        if solo.final_err is None or res.final_err is None:
            assert solo.final_err == res.final_err
        else:
            np.testing.assert_allclose(solo.final_err, res.final_err,
                                       rtol=1e-4)
        if res.early_exit:
            assert res.nfe < req.nfe and res.final_err <= _EE_POLICY["tol"]
        # pair-less solvers must always run their full budget
        if req.solver in ("ddim", "euler", "em", "ddim_eta", "seeds1"):
            assert not res.early_exit and res.nfe == req.nfe

    n_exec = eng.num_executors
    warm = _drive(eng, workload)
    assert eng.num_executors == n_exec, "warm early-exit replay recompiled"
    assert all(r.compile_s == 0.0 for r in warm.values())
    for uid in got:
        np.testing.assert_array_equal(warm[uid].tokens, got[uid].tokens)
        assert warm[uid].nfe == got[uid].nfe


@pytest.mark.parametrize("solver", ["sndeis2", "dpm2m", "scire2"])
def test_new_family_early_exit_via_retire_policy(diff_setup, solver):
    """The next-gen families with embedded pairs retire through the SAME
    RetirePolicy path as tab2 -- for sndeis that exercises the ``E * nu``
    normalized estimate end-to-end (the acceptance criterion that
    plan_sndeis early-exits where a pair exists). Early exits are bitwise
    vs a solo engine under the same controller, and pair-carrying rows
    spend fewer NFEs than budgeted."""
    from repro.core.adaptive import RetirePolicy

    params, cfg = diff_setup
    reqs = [Request(uid=i, seq_len=8, nfe=8 + 2 * (i % 2), solver=solver,
                    seed=i) for i in range(3)]
    eng = DiffusionServeEngine(params, cfg, seq_len_buckets=(8,), max_group=4,
                               retire=RetirePolicy(**_EE_POLICY))
    got = {r.uid: r for r in eng.serve(list(reqs))}
    assert sum(r.early_exit for r in got.values()) > 0
    solo = DiffusionServeEngine(params, cfg, seq_len_buckets=(8,),
                                retire=RetirePolicy(**_EE_POLICY))
    for q in reqs:
        want = solo.serve([Request(uid=q.uid, seq_len=q.seq_len, nfe=q.nfe,
                                   solver=q.solver, seed=q.seed)])[0]
        res = got[q.uid]
        np.testing.assert_array_equal(want.tokens, res.tokens)
        assert (want.early_exit, want.nfe) == (res.early_exit, res.nfe)
        if res.early_exit:
            assert res.nfe < q.nfe and res.final_err <= _EE_POLICY["tol"]


# ------------------------------------------- cancellation (race-tolerant)
def _drive_with_cancels(eng, workload, cancels):
    """_drive plus cancel orders keyed to ticks: {tick: [uid, ...]}.
    Cancels are best-effort -- a request may legitimately finish first."""
    pending = sorted(workload, key=lambda a: a[0])
    i, t, results = 0, 0, []
    while i < len(pending) or eng.busy:
        while i < len(pending) and pending[i][0] <= t:
            eng.submit(pending[i][1])
            i += 1
        for uid in cancels.get(t, ()):
            eng.cancel(uid)
        results += eng.tick()
        t += 1
        assert t < _MAX_TICKS, "scheduler failed to drain (starvation?)"
    return {r.uid: r for r in results}


@pytest.mark.parametrize("fuzz_seed", [0, 1])
def test_fuzz_cancellation_conservation_and_survivors(diff_setup,
                                                      solo_engine, fuzz_seed):
    """Cancellation storms: every request gets exactly one outcome, the
    registry conserves requests (submitted == completed + cancelled), a
    cancelled request delivers no sample, and cancellation never perturbs a
    survivor (bitwise-vs-solo through the same take_rows recycle path as
    deadline eviction). Cancels of unknown/finished uids are no-ops."""
    params, cfg = diff_setup
    rng = np.random.RandomState(100 + fuzz_seed)
    workload = _gen_workload(fuzz_seed, n=10)
    # cancel a random third across the drain window; some orders will lose
    # the race with completion on purpose (no-op then)
    cancels: dict = {}
    targets = rng.choice(10, size=4, replace=False)
    for uid in targets:
        cancels.setdefault(int(rng.randint(0, 12)), []).append(int(uid))
    cancels.setdefault(0, []).append(999)         # never submitted: no-op
    eng = _make_engine(params, cfg, join=True)
    got = _drive_with_cancels(eng, workload, cancels)
    assert len(got) == len(workload)              # one outcome per request

    m = eng.metrics
    submitted = m.get("serve_submitted_total").value
    completed = m.get("serve_completed_total").value
    cancelled = m.get("serve_cancelled_total").value
    assert submitted == len(workload)
    assert completed + cancelled == submitted     # conservation
    assert cancelled == sum(r.cancelled for r in got.values())
    assert eng.cancel(999) is False               # unknown uid: no-op

    for _, req in workload:
        res = got[req.uid]
        if res.cancelled:
            assert req.uid in set(int(u) for us in cancels.values()
                                  for u in us)
            assert res.tokens.size == 0 and res.nfe == 0
        else:
            solo = solo_engine.serve([Request(
                uid=req.uid, seq_len=req.seq_len, nfe=req.nfe,
                solver=req.solver, eta=req.eta, seed=req.seed)])[0]
            np.testing.assert_array_equal(solo.tokens, res.tokens)


def test_driver_cancel_on_own_stream(diff_setup):
    """Through the driver, a cancelled request fails with Cancelled on ITS
    OWN handle (stream closed, driver alive), later submissions still
    compute bitwise-identical samples, and stats() conserves requests."""
    from repro.serving.driver import ServeDriver
    from repro.serving.engine import Cancelled

    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg, seq_len_buckets=(8,))
    with ServeDriver(eng) as drv:
        # warm the executor so the cancel below races a real solve window
        drv.submit(Request(uid=990, seq_len=8, nfe=3, solver="ddim",
                           seed=0)).result(timeout=120)
        h1 = drv.submit(Request(uid=1, seq_len=8, nfe=400, solver="ddim",
                                seed=1))
        h2 = drv.submit(Request(uid=2, seq_len=8, nfe=3, solver="ddim",
                                seed=2))
        assert drv.cancel(1) is True
        with pytest.raises(Cancelled) as ei:
            h1.result(timeout=60)
        assert ei.value.result.cancelled and ei.value.result.tokens.size == 0
        res2 = h2.result(timeout=60)
        assert not res2.cancelled and res2.tokens.size > 0
        assert drv.cancel(1) is False          # already finished: no-op
        assert drv.cancel(777) is False        # never submitted: no-op
        # the driver survived and still serves, bitwise-stable
        late = drv.submit(Request(uid=3, seq_len=8, nfe=3, solver="ddim",
                                  seed=2))
        np.testing.assert_array_equal(late.result(timeout=60).tokens,
                                      res2.tokens)
        s = drv.stats()
        assert s["cancelled"] == 1
    s = drv.stats()
    assert s["in_flight"] == 0
    # driver-side conservation: all submissions resolved exactly once
    assert s["submitted"] == 4
    m = eng.metrics
    assert m.get("serve_completed_total").value + \
        m.get("serve_cancelled_total").value == s["submitted"]


# -------------------------------------- deadline enforcement (storm fuzz)
def _gen_deadline_storm(fuzz_seed: int, n: int):
    """Seed -> [(arrival_tick, Request)] with deadlines across the whole
    spectrum: None (best-effort), 1 microsecond (expired before any tick can
    admit it -> deterministic pending-shed), a few hundred ms (may expire
    mid-flight depending on host speed -- genuinely racy on purpose), and
    60 s (never expires inside a test run). The conservation and
    bitwise-vs-solo invariants below are schedule-independent, so the racy
    band is safe to fuzz."""
    rng = np.random.RandomState(fuzz_seed)
    out = []
    for uid in range(n):
        solver = _SOLVERS[rng.randint(len(_SOLVERS))]
        deadline = [None, 1e-6, 0.2, 60.0][rng.randint(4)]
        out.append((int(rng.randint(0, 6)), Request(
            uid=uid,
            seq_len=int(rng.randint(5, 9)),
            nfe=int(rng.randint(3, 9)),
            solver=solver,
            eta=1.0 if solver == "ddim_eta" else None,
            seed=int(rng.randint(0, 100)),
            priority=int(rng.randint(0, 3)),
            deadline_s=deadline)))
    return out


@pytest.mark.parametrize("join", [True, False], ids=["joins_on", "joins_off"])
@pytest.mark.parametrize("fuzz_seed", [0, 1, 2])
def test_fuzz_deadline_storm_conservation_and_survivors(diff_setup,
                                                        solo_engine, join,
                                                        fuzz_seed):
    """Deadline storms: every submitted request gets EXACTLY one outcome
    (sample or deadline_exceeded Result, never both, never neither), the
    registry conserves requests (submitted == completed + evicted), and
    eviction never perturbs a surviving request's sample (survivors stay
    bitwise-vs-solo -- eviction recycles rows through the same take_rows
    boundary path as normal retirement)."""
    params, cfg = diff_setup
    workload = _gen_deadline_storm(fuzz_seed, n=12)
    eng = DiffusionServeEngine(params, cfg, steps_per_tick=2, aging_ticks=3,
                               max_group=3, join=join, seq_len_buckets=(8,),
                               enforce_deadlines=True)
    got = _drive(eng, workload)
    assert len(got) == len(workload)          # one outcome per request
    assert sorted(got) == [r.uid for _, r in sorted(workload,
                                                    key=lambda a: a[1].uid)]

    m = eng.metrics
    submitted = m.get("serve_submitted_total").value
    completed = m.get("serve_completed_total").value
    evicted = m.get("serve_deadline_evicted_total").value
    assert submitted == len(workload)
    assert completed + evicted == submitted   # conservation
    assert completed == sum(not r.deadline_exceeded for r in got.values())
    assert evicted == sum(r.deadline_exceeded for r in got.values())

    for _, req in workload:
        res = got[req.uid]
        if res.deadline_exceeded:
            # only requests that HAD a finite deadline can be evicted, and
            # an evicted request delivers no sample
            assert req.deadline_s is not None and req.deadline_s < 60.0
            assert res.tokens.size == 0 and res.nfe == 0
            assert res.queue_wait_s >= 0.0 and res.latency_s >= 0.0
        else:
            solo = solo_engine.serve([Request(
                uid=req.uid, seq_len=req.seq_len, nfe=req.nfe,
                solver=req.solver, eta=req.eta, seed=req.seed)])[0]
            np.testing.assert_array_equal(solo.tokens, res.tokens)
    # microsecond deadlines can never outrun the first admission pass
    for _, req in workload:
        if req.deadline_s == 1e-6:
            assert got[req.uid].deadline_exceeded


def test_deadline_enforcement_off_keeps_advisory_behavior(diff_setup):
    """The default engine treats deadlines as ordering hints only: an
    already-expired deadline must still be served to completion."""
    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg, seq_len_buckets=(8,))
    res = eng.serve([Request(uid=0, seq_len=8, nfe=3, solver="ddim", seed=0,
                             deadline_s=1e-6)])[0]
    assert not res.deadline_exceeded
    assert res.tokens.size > 0
    assert eng.metrics.get("serve_deadline_evicted_total").value == 0


def test_driver_deadline_exceeded_on_own_stream_with_shed_conservation(
        diff_setup):
    """Through the driver, an evicted request fails with DeadlineExceeded on
    ITS OWN handle (event stream closed, driver alive and serving), sheds
    are counted, and the stats()/registry view conserves requests:
    driver_submitted == completed + deadline_evicted, and every submit call
    is either accepted or shed."""
    from repro.serving.driver import QueueFull, ServeDriver
    from repro.serving.engine import DeadlineExceeded

    params, cfg = diff_setup
    eng = DiffusionServeEngine(params, cfg, seq_len_buckets=(8,),
                               enforce_deadlines=True)
    eng.serve([Request(uid=990, seq_len=8, nfe=3, solver="ddim", seed=0)])
    # the warm serve above already moved the engine's counters; conservation
    # below is asserted on deltas from here
    m = eng.metrics
    base_completed = m.get("serve_completed_total").value
    base_evicted = m.get("serve_deadline_evicted_total").value
    with ServeDriver(eng, max_pending=3) as drv:
        handles, n_submits = {}, 0
        for i in range(3):
            handles[i] = drv.submit(Request(
                uid=i, seq_len=8, nfe=3, solver="ddim", seed=i,
                deadline_s=1e-6 if i == 0 else None))
            n_submits += 1
        # the in-flight set is full: this one must shed with QueueFull
        extra = drv.submit(Request(uid=99, seq_len=8, nfe=3, solver="ddim",
                                   seed=9))
        n_submits += 1
        with pytest.raises(QueueFull):
            extra.result(timeout=5)

        with pytest.raises(DeadlineExceeded):
            handles[0].result(timeout=30)
        assert list(handles[0]) == []          # stream closed, no events
        for i in (1, 2):
            res = handles[i].result(timeout=30)
            assert not res.deadline_exceeded and res.tokens.size > 0
        # the driver survived the eviction and still serves
        late = drv.submit(Request(uid=100, seq_len=8, nfe=3, solver="ddim",
                                  seed=1))
        n_submits += 1
        # same (solver, nfe, seed, seq_len) as uid=1: scheduling after an
        # eviction still computes the same sample
        np.testing.assert_array_equal(late.result(timeout=30).tokens,
                                      handles[1].result().tokens)

        s = drv.stats()
        assert s["shed"] == 1
        assert s["submitted"] == n_submits - s["shed"]
    # drained: exact conservation (deltas exclude the warm-up serve)
    s = drv.stats()
    assert s["in_flight"] == 0
    completed = m.get("serve_completed_total").value - base_completed
    evicted = m.get("serve_deadline_evicted_total").value - base_evicted
    assert completed + evicted == s["submitted"]
    assert evicted == 1


# --------------------------------------- 8-device host mesh (subprocess)
_CHILD_FUZZ = """
import os
import jax, numpy as np
assert jax.device_count() == 8, jax.device_count()
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serving.engine import DiffusionServeEngine, Request
from repro.launch.mesh import make_request_mesh

cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
params = T.init_params(cfg, jax.random.PRNGKey(0))

rng = np.random.RandomState(3)
# mixed-generation traffic UNDER sharding: classic names plus one
# representative per next-gen family (dpm multistep, seeds, scire, sn-deis
# with its nu coefficient leaf, which must shard like any other plan leaf)
workload = [(int(rng.randint(0, 5)), Request(
    uid=i, seq_len=int(rng.randint(5, 9)), nfe=int(rng.choice([3, 5, 7])),
    solver=["ddim", "dpm2m", "seeds1", "scire2", "sndeis2", "em"][i %% 6],
    seed=int(rng.randint(100)), priority=int(rng.randint(2))))
    for i in range(10)]

def drive(eng):
    pending = sorted(workload, key=lambda a: a[0])
    i, t, res = 0, 0, []
    while i < len(pending) or eng.busy:
        while i < len(pending) and pending[i][0] <= t:
            eng.submit(pending[i][1]); i += 1
        res += eng.tick(); t += 1
        assert t < 2000
    return {r.uid: r for r in res}

base = DiffusionServeEngine(params, cfg, max_group=16, seq_len_buckets=(8,))
want = drive(base)
eng = DiffusionServeEngine(params, cfg, max_group=16, seq_len_buckets=(8,),
                           mesh=make_request_mesh())
got = drive(eng)
assert want.keys() == got.keys()
for uid in want:                     # sharded fuzz == single-device fuzz
    np.testing.assert_array_equal(got[uid].tokens, want[uid].tokens)
assert eng.wasted_row_steps == 0     # join-slot/structural filler excluded
batches = sorted({k[1] for k in eng._compiled})
assert all(b %% 8 == 0 for b in batches), batches
n = eng.num_executors
again = drive(eng)
assert eng.num_executors == n, "warm sharded fuzz replay recompiled"
for uid in want:
    np.testing.assert_array_equal(again[uid].tokens, want[uid].tokens)
print("FUZZ_MESH_OK joined=%%d" %% eng.joined_requests)
"""


_CHILD_FUZZ_EE = """
import os
import jax, numpy as np
assert jax.device_count() == 8, jax.device_count()
from repro.configs.base import get_config
from repro.core.adaptive import RetirePolicy
from repro.models import transformer as T
from repro.serving.engine import DiffusionServeEngine, Request
from repro.launch.mesh import make_request_mesh

cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
params = T.init_params(cfg, jax.random.PRNGKey(0))

rng = np.random.RandomState(7)
workload = [(int(rng.randint(0, 5)), Request(
    uid=i, seq_len=int(rng.randint(5, 9)), nfe=int(rng.choice([5, 7, 9])),
    solver=["tab2", "ddim", "tab2"][i %% 3],
    seed=int(rng.randint(100)), priority=int(rng.randint(2))))
    for i in range(10)]

def drive(eng):
    pending = sorted(workload, key=lambda a: a[0])
    i, t, res = 0, 0, []
    while i < len(pending) or eng.busy:
        while i < len(pending) and pending[i][0] <= t:
            eng.submit(pending[i][1]); i += 1
        res += eng.tick(); t += 1
        assert t < 2000
    return {r.uid: r for r in res}

pol = RetirePolicy(tol=1.0, min_k=2)
base = DiffusionServeEngine(params, cfg, max_group=16, seq_len_buckets=(8,),
                            retire=pol)
want = drive(base)
assert any(r.early_exit for r in want.values())   # dimension exercised
eng = DiffusionServeEngine(params, cfg, max_group=16, seq_len_buckets=(8,),
                           mesh=make_request_mesh(), retire=pol)
got = drive(eng)
assert want.keys() == got.keys()
for uid in want:   # sharded early-exit fuzz == single-device early-exit fuzz
    np.testing.assert_array_equal(got[uid].tokens, want[uid].tokens)
    assert got[uid].nfe == want[uid].nfe
    assert got[uid].early_exit == want[uid].early_exit
    # the estimate's weighted combination is only ULP-stable across the
    # sharded/unsharded EXECUTABLES (different fusion); decisions matched
    if want[uid].final_err is not None:
        np.testing.assert_allclose(got[uid].final_err, want[uid].final_err,
                                   rtol=1e-4)
m_b, m_s = base.metrics, eng.metrics
assert m_s.get("serve_saved_nfe_total").value == \\
    m_b.get("serve_saved_nfe_total").value
# bitwise-vs-solo-with-same-controller holds exactly under the SAME mesh:
# same executable family, same per-row estimate, same retire step
solo = DiffusionServeEngine(params, cfg, seq_len_buckets=(8,),
                            mesh=make_request_mesh(), retire=pol)
for _, req in workload:
    s = solo.serve([Request(uid=req.uid, seq_len=req.seq_len, nfe=req.nfe,
                            solver=req.solver, seed=req.seed)])[0]
    g = got[req.uid]
    np.testing.assert_array_equal(s.tokens, g.tokens)
    assert (s.nfe, s.early_exit, s.final_err) == \\
        (g.nfe, g.early_exit, g.final_err)
n = eng.num_executors
again = drive(eng)
assert eng.num_executors == n, "warm sharded early-exit replay recompiled"
print("FUZZ_MESH_EE_OK early=%%d" %%
      int(m_s.get("serve_early_exit_total").value))
"""


@pytest.mark.slow  # compiles sharded estimate-carrying executors
def test_fuzz_early_exit_sharded_8dev_bitwise():
    """The early-exit invariants hold UNDER request-axis sharding: an
    8-device mesh engine with the same RetirePolicy retires the same rows at
    the same steps with bitwise-identical samples and conserved saved-NFE
    accounting (the per-row Linf estimate shards over the request axis and
    is reduction-order independent)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _CHILD_FUZZ_EE % ()],
                         capture_output=True, text=True, timeout=1800,
                         env=env)
    assert out.returncode == 0, f"child failed:\n{out.stdout}\n{out.stderr}"
    assert "FUZZ_MESH_EE_OK" in out.stdout, out.stdout


@pytest.mark.slow  # compiles sharded executors for several batch buckets
def test_fuzz_traffic_sharded_8dev_bitwise():
    """The fuzz invariants hold UNDER request-axis sharding: a forced
    8-device host mesh serves the same randomized workload bit-identically
    to the single-device engine, with structural/join filler excluded from
    waste and zero warm recompiles on replay."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", _CHILD_FUZZ % ()],
                         capture_output=True, text=True, timeout=1800,
                         env=env)
    assert out.returncode == 0, f"child failed:\n{out.stdout}\n{out.stderr}"
    assert "FUZZ_MESH_OK" in out.stdout, out.stdout
