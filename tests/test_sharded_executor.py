"""Data-parallel sharded stacked executor: request-axis spec rules,
mesh-arg sampling, engine integration on a degenerate 1-device mesh, and
(subprocess, 8 forced host devices) bitwise equality of the sharded serving
path with the single-device path -- including mid-flight compaction with
zero warm recompiles.

The multi-device cases must run in a subprocess because
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` only takes effect
BEFORE jax is imported (conftest already imported it here).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (VPSDE, get_timesteps, inert_row, init_state,
                        make_plan, sample, stack_plans, step)
from repro.diffusion.analytic import GaussianData
from repro.launch.mesh import make_request_mesh, mesh_fingerprint
from repro.sharding import rules as R

SDE = VPSDE()
TS = get_timesteps(SDE, 6, "quadratic")


class FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


def _problem(batch):
    # float32 like the serving stack: under x64, placing committed shardings
    # can change XLA's fori_loop fusion by 1 ulp (the per-step AOT executors
    # serving uses are bitwise either way; see sample()'s mesh docstring)
    g = GaussianData(SDE, mean=np.full(4, 1.5), var=np.full(4, 0.25))
    xT = jax.random.normal(jax.random.PRNGKey(0), (batch, 4),
                           jnp.float32) * SDE.prior_std()
    raw = g.eps_fn()
    return (lambda x, t: raw(x, t).astype(x.dtype)), xT


# ------------------------------------------------------------- spec rules
def test_plan_specs_shard_request_axis_when_divisible():
    mesh = FakeMesh(data=4)
    plan = stack_plans([make_plan("tab2", SDE, TS)] * 4)
    specs = R.plan_specs(plan, mesh)
    assert specs.ts == P("data", None)
    assert all(s[0] == "data" for s in specs.coeffs.values())
    # non-divisible batch falls back to replication leaf-wise
    plan3 = stack_plans([make_plan("tab2", SDE, TS)] * 3)
    specs3 = R.plan_specs(plan3, mesh)
    assert specs3.ts == P(None, None)
    # unstacked plans replicate entirely
    specs1 = R.plan_specs(make_plan("tab2", SDE, TS), mesh)
    assert specs1.ts == P()


def test_plan_specs_cover_new_family_coeff_leaves():
    """The spec rule is leaf-generic: the sndeis per-step ``nu`` key, the
    seeds noise scale ``s``, the scire stage tableaus and the lambda-basis
    dpm tables all pick up the request axis on a stacked plan -- there is no
    per-family spec table to fall out of date."""
    mesh = FakeMesh(data=4)
    for name in ("sndeis2", "seeds1", "scire2", "dpm3m"):
        plan = stack_plans([make_plan(name, SDE, TS)] * 4)
        specs = R.plan_specs(plan, mesh)
        assert specs.ts == P("data", None)
        assert set(specs.coeffs) == set(plan.coeffs)
        for key_, s in specs.coeffs.items():
            assert s[0] == "data", (name, key_, s)
    assert "nu" in R.plan_specs(
        stack_plans([make_plan("sndeis2", SDE, TS)] * 4), mesh).coeffs


def test_state_specs_layout():
    """x shards on axis 0, hist on axis 1 (history axis leads), keys on
    axis 0, step counter replicates."""
    mesh = FakeMesh(data=2)
    plan = stack_plans([make_plan("em", SDE, TS)] * 2)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in (1, 2)])
    _, xT = _problem(batch=2)
    st = init_state(plan, xT, keys)
    specs = R.state_specs(st, mesh)
    assert specs.x == P("data", None)
    assert specs.hist == P(None, "data", None)
    assert specs.key == P("data", None)
    assert specs.k == P()
    # unstacked state (single PRNG key) replicates
    solo = init_state(make_plan("em", SDE, TS), xT[0], jax.random.PRNGKey(0))
    s1 = R.state_specs(solo, mesh)
    assert s1.x == P() and s1.key == P()


def test_inert_row_is_inert_and_stackable():
    """An inert filler row has the member's signature, zero weight-like
    coefficients (its iterate update is the zero map, its noise scale zero),
    and in-domain times -- stepping it stays finite forever."""
    for name in ("tab2", "em", "rho_heun", "pndm"):
        plan = make_plan(name, SDE, get_timesteps(
            SDE, 8 if name == "pndm" else 6, "quadratic"))
        filler = inert_row(plan)
        assert filler.signature == plan.signature and filler.nfe == 0
        stacked = stack_plans([plan, filler])
        eps, xT = _problem(batch=2)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in (1, 0)])
        st = init_state(stacked, xT, keys)
        for k in range(stacked.n_steps):
            st = step(stacked, k, st, eps)
        assert np.all(np.isfinite(np.asarray(st.x)))
        # the real row is untouched by the filler riding along (sample vs
        # sample: the step-loop differs from fori_loop by fusion only)
        full = sample(stacked, eps, xT, keys)
        solo = sample(stack_plans([plan]), eps, xT[:1], keys[:1])
        np.testing.assert_array_equal(np.asarray(full[0]),
                                      np.asarray(solo[0]))


# In the full tier-1 run the suite executes with a forced host device count
# (test_dryrun_units imports repro.launch.dryrun at collection, which sets
# XLA_FLAGS before backends initialize), so in-process meshes must cap their
# data axis rather than assume 1 device.
def _small_mesh():
    return make_request_mesh(min(jax.device_count(), 4))


def test_mesh_fingerprint_distinguishes_layouts():
    m1 = _small_mesh()
    assert mesh_fingerprint(m1) == mesh_fingerprint(_small_mesh())
    fp = mesh_fingerprint(m1)
    assert fp[0] == (("data", min(jax.device_count(), 4)),)


# ------------------------------------------------- mesh-arg sample()/step()
def test_sample_and_step_with_mesh_equal_unsharded():
    """On however many devices exist (1 in the default test env), the mesh
    arg never changes WHAT is computed: the per-step path (what serving
    executes) is bit-identical with and without the mesh; the full-solve
    ``fori_loop`` matches to machine epsilon (the SPMD partitioner may fuse
    the loop body differently -- the same caveat as ``sample`` vs an eagerly
    dispatched ``step`` loop, see the sampler module docstring)."""
    mesh = _small_mesh()
    n = min(jax.device_count(), 4)
    plans = [make_plan("em", SDE, TS)] * n + [make_plan("em", SDE, TS)] * n
    eps, xT = _problem(batch=2 * n)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(2 * n)])
    stacked = stack_plans(plans)
    st_plain = init_state(stacked, xT, keys)
    st_mesh = init_state(stacked, xT, keys)
    for k in range(stacked.n_steps):
        st_plain = step(stacked, k, st_plain, eps)
        st_mesh = step(stacked, k, st_mesh, eps, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(st_mesh.x),
                                  np.asarray(st_plain.x))
    want = sample(stacked, eps, xT, keys)
    got = sample(stacked, eps, xT, keys, mesh=mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-7, atol=3e-7)


# --------------------------------------------- engine on a degenerate mesh
@pytest.fixture(scope="module")
def diff_setup():
    from repro.configs.base import get_config
    from repro.models import transformer as T
    cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return params, cfg


def test_engine_mesh_bitwise_equals_unsharded(diff_setup):
    """A small ('data',) mesh (1 device standalone; up to 4 when the suite
    runs under a forced host device count) exercises the whole sharded code
    path -- NamedSharding placements, sharded AOT executors, mesh-keyed
    compile cache, group-size rounding -- and must reproduce the unsharded
    engine bit-for-bit, warm with zero recompiles."""
    from repro.serving.engine import DiffusionServeEngine, Request
    params, cfg = diff_setup
    reqs = [Request(uid=i, seq_len=16, nfe=[3, 6][i % 2],
                    solver=["ddim", "euler"][i % 2], seed=i)
            for i in range(4)]
    base = DiffusionServeEngine(params, cfg)
    want = {r.uid: r.tokens for r in base.serve(list(reqs))}
    eng = DiffusionServeEngine(params, cfg, mesh=_small_mesh())
    got = {r.uid: r.tokens for r in eng.serve(list(reqs))}
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid])
    # cache keys carry the mesh fingerprint; warm serve never recompiles
    assert all(k[3] is not None for k in eng._compiled)
    n = eng.num_executors
    again = {r.uid: r.tokens for r in eng.serve(list(reqs))}
    assert eng.num_executors == n
    for uid in want:
        np.testing.assert_array_equal(again[uid], want[uid])


# ----------------------------------------- 8-device host mesh (subprocess)
_CHILD_COMMON = """
import os
import jax, numpy as np
assert jax.device_count() == 8, jax.device_count()
"""

_CHILD_SAMPLER = _CHILD_COMMON + """
import jax.numpy as jnp
from repro.core import VPSDE, get_timesteps, make_plan, sample, stack_plans
from repro.diffusion.analytic import GaussianData
from repro.launch.mesh import make_request_mesh

SDE = VPSDE()
TS = get_timesteps(SDE, 6, "quadratic")
g = GaussianData(SDE, mean=np.full(4, 1.5), var=np.full(4, 0.25))
eps = g.eps_fn()
xT = jax.random.normal(jax.random.PRNGKey(0), (8, 4)) * SDE.prior_std()
# stacked STOCHASTIC plans with distinct per-request seeds: the sharded solve
# must reproduce each row's key chain exactly
plans = [make_plan("em", SDE, TS) if i % 2 else
         make_plan("ddim_eta", SDE, TS, eta=1.0) for i in range(8)]
keys = jnp.stack([jax.random.PRNGKey(100 + i) for i in range(8)])
stacked = stack_plans(plans)
want = sample(stacked, eps, xT, keys)
got = sample(stacked, eps, xT, keys, mesh=make_request_mesh())
np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
# and each sharded row equals its SOLO single-device solve (seed contract)
for i in range(8):
    solo = sample(stack_plans([plans[i]]), eps, xT[i:i+1], keys[i:i+1])
    np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(solo[0]))
print("SAMPLER_OK")
"""

_CHILD_ENGINE = _CHILD_COMMON + """
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serving.engine import DiffusionServeEngine, Request
from repro.launch.mesh import make_request_mesh

cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
params = T.init_params(cfg, jax.random.PRNGKey(0))
# a mesh whose data axis exceeds max_group is unsatisfiable (the smallest
# placeable group would break the bound) and must be rejected at init
try:
    DiffusionServeEngine(params, cfg, max_group=4, mesh=make_request_mesh())
except ValueError as e:
    assert "max_group" in str(e)
else:
    raise AssertionError("max_group < data-axis size must raise")
# ragged NFE so rows retire mid-flight; max_group=16 > data axis 8 so
# compaction crosses a multiple boundary (16 -> 8) UNDER sharding; em rows
# make the group stochastic with distinct seeds
reqs = [Request(uid=i, seq_len=16, nfe=[3, 7][i % 2], solver="ddim", seed=i)
        for i in range(12)]
reqs += [Request(uid=100 + i, seq_len=16, nfe=4, solver="em", seed=50 + i)
         for i in range(3)]
base = DiffusionServeEngine(params, cfg, max_group=16)
want = {r.uid: r.tokens for r in base.serve(list(reqs))}
eng = DiffusionServeEngine(params, cfg, max_group=16,
                           mesh=make_request_mesh())
got = {r.uid: r.tokens for r in eng.serve(list(reqs))}
assert want.keys() == got.keys()
for uid in want:
    np.testing.assert_array_equal(got[uid], want[uid])
batches = sorted(k[1] for k in eng._compiled)
assert all(b % 8 == 0 for b in batches), batches   # groups place evenly
assert 8 in batches and 16 in batches, batches     # compaction hit 16 -> 8
# warm pass: compaction-under-sharding reuses the mesh-keyed cache -- zero
# recompiles -- and stays bitwise
n = eng.num_executors
again = {r.uid: r.tokens for r in eng.serve(list(reqs))}
assert eng.num_executors == n, "warm sharded serve recompiled"
for uid in want:
    np.testing.assert_array_equal(again[uid], want[uid])
# a ragged group pinned at the smallest placeable multiple (exactly 8 real
# rows on the 8-way axis, so compaction can never shrink it): retired rows
# become structural filler, not waste -- same status as compaction-retained
# rows
pinned = DiffusionServeEngine(params, cfg, mesh=make_request_mesh())
pinned.serve([Request(uid=200 + i, seq_len=16, nfe=[3, 7][i % 2],
                      solver="ddim", seed=i) for i in range(8)])
assert pinned.wasted_row_steps == 0, pinned.wasted_row_steps
print("ENGINE_OK")
"""


_CHILD_JOIN = _CHILD_COMMON + """
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serving.engine import DiffusionServeEngine, Request
from repro.launch.mesh import make_request_mesh

cfg = get_config("gemma_2b").reduced().with_(objective="diffusion")
params = T.init_params(cfg, jax.random.PRNGKey(0))
# an 8-row ragged group on the 8-way axis: 4 rows retire after 3 steps,
# leaving 4 slots; 4 joiners then refill them at the SAME batch size --
# the never-drain, never-recompile steady state
first = [Request(uid=i, seq_len=16, nfe=[3, 8][i % 2], solver="ddim", seed=i)
         for i in range(8)]
late = [Request(uid=100 + i, seq_len=16, nfe=4, solver="euler", seed=50 + i)
        for i in range(4)]
eng = DiffusionServeEngine(params, cfg, max_group=8, mesh=make_request_mesh())
out = []
for r in first:
    eng.submit(r)
for _ in range(3):
    out += eng.tick()              # nfe=3 rows retire at tick 3
for r in late:
    eng.submit(r)
while eng.busy:
    out += eng.tick()
assert eng.joined_requests == 4, eng.joined_requests
assert eng.wasted_row_steps == 0, eng.wasted_row_steps
# retired rows became join slots in place: ONE executor bucket, batch 8
assert {k[1] for k in eng._compiled} == {8}, sorted(eng._compiled)
got = {r.uid: r.tokens for r in out}
assert len(got) == 12
solo = DiffusionServeEngine(params, cfg)   # single-device solo reference
for r in first + late:
    np.testing.assert_array_equal(
        solo.serve([Request(uid=r.uid, seq_len=16, nfe=r.nfe,
                            solver=r.solver, seed=r.seed)])[0].tokens,
        got[r.uid])
print("JOIN_OK")
"""


def _run_child(script: str, marker: str, timeout: int) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"child failed:\n{out.stdout}\n{out.stderr}"
    assert marker in out.stdout, out.stdout


def test_8dev_sampler_bitwise_stochastic_stack():
    """Forced 8-device host mesh: a stacked stochastic solve (em + ddim_eta,
    distinct seeds) sharded over the request axis is bitwise identical to the
    unsharded stack AND to each row's solo solve."""
    _run_child(_CHILD_SAMPLER, "SAMPLER_OK", timeout=600)


@pytest.mark.slow  # compiles an 8-row sharded executor + solo references
def test_8dev_engine_join_refills_group_at_fixed_batch():
    """Forced 8-device host mesh: retired rows of an 8-row ragged group
    become join slots -- late same-family requests are spliced in at the
    SAME batch size (one executor bucket total, zero waste) and every
    sample, veteran and joiner, is bitwise-identical to a single-device
    solo serve."""
    _run_child(_CHILD_JOIN, "JOIN_OK", timeout=900)


@pytest.mark.slow  # compiles 16- and 8-row sharded+unsharded executors (~3min)
def test_8dev_engine_compaction_under_sharding_zero_recompiles():
    """Forced 8-device host mesh, serving layer: ragged groups round up to
    multiples of 8 with inert filler, compact 16 -> 8 mid-flight under
    sharding, produce bitwise-identical samples to the single-device engine,
    and a warm pass runs with zero recompiles."""
    _run_child(_CHILD_ENGINE, "ENGINE_OK", timeout=900)
