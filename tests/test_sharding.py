"""Sharding rules: divisibility fallbacks, spec validity, and a real
multi-device pjit run on a small host mesh (8 fake CPU devices via conftest?
-- no: tests must see 1 device per the assignment, so these tests validate
SPECS structurally and run pjit on a 1x1 mesh; the 512-device path is covered
by the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.sharding import rules as R


class FakeMesh:
    """Structural stand-in with arbitrary axis sizes (no devices needed)."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = FakeMesh(data=16, model=16)
MESH_POD = FakeMesh(pod=2, data=16, model=16)


def _params_shape(arch, objective="ar"):
    cfg = get_config(arch).with_(objective=objective)
    return cfg, jax.eval_shape(lambda k: T.init_params(cfg, k),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "cifar10_scorenet"])
@pytest.mark.parametrize("mesh", [MESH, MESH_POD], ids=["pod1", "pod2"])
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible(arch, mesh, fsdp):
    """Every assigned axis must divide evenly -- the engine's core contract."""
    cfg, shape = _params_shape(arch)
    specs = R.param_specs(shape, mesh, fsdp=fsdp)

    def check(leaf, spec):
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % size == 0, (arch, leaf.shape, spec)

    jax.tree.map(check, shape, specs, is_leaf=lambda x: isinstance(x, P))
    # tree structures match
    assert jax.tree.structure(shape) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P))


def test_whisper_heads_replicate_but_dff_shards():
    """whisper-tiny: q_dim=384 shards on 16 (24/shard); d_ff=1536 shards."""
    cfg, shape = _params_shape("whisper_tiny")
    specs = R.param_specs(shape, MESH, fsdp=False)
    wq_spec = specs["blocks"]["slot0"]["attn"]["wq"]
    assert wq_spec[-1] == "model"          # 384 % 16 == 0
    mlp_spec = specs["blocks"]["slot0"]["mlp"]["w_up"]
    assert mlp_spec[-1] == "model"


def test_odd_vocab_replicates_embed_rows():
    """whisper vocab 51865 is not divisible by 16 -> embed dim0 replicated."""
    cfg, shape = _params_shape("whisper_tiny")
    specs = R.param_specs(shape, MESH, fsdp=False)
    assert specs["embed"][0] is None
    # granite vocab 49155 also odd
    cfg2, shape2 = _params_shape("granite_3_8b")
    specs2 = R.param_specs(shape2, MESH, fsdp=False)
    assert specs2["embed"][0] is None
    # gemma 256000 divides
    cfg3, shape3 = _params_shape("gemma_2b")
    specs3 = R.param_specs(shape3, MESH, fsdp=False)
    assert specs3["embed"][0] == "model"


def test_fsdp_adds_data_axis_on_big_weights():
    cfg, shape = _params_shape("grok_1_314b")
    specs = R.param_specs(shape, MESH, fsdp=True)
    moe_up = specs["blocks"]["slot0"]["moe"]["w_up"]
    assert moe_up[-1] == "model" and moe_up[-2] == "data"


def test_batch_specs():
    mesh = MESH_POD
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = R.batch_specs(batch, mesh)
    assert specs["tokens"] == P(("pod", "data"), None)
    # batch=1 cannot shard over 32 -> replicated
    batch2 = {"tokens": jax.ShapeDtypeStruct((1, 64), jnp.int32)}
    assert R.batch_specs(batch2, mesh)["tokens"] == P(None, None)


@pytest.mark.parametrize("arch", ["glm4_9b", "mamba2_2p7b", "jamba_1p5_large",
                                  "h2o_danube_3_4b"])
def test_cache_specs_divisible(arch):
    cfg = get_config(arch).with_(objective="ar")
    cache_shape = jax.eval_shape(lambda: T.init_cache(cfg, 128, 32768, jnp.bfloat16))
    specs = R.cache_specs(cache_shape, MESH)

    def check(leaf, spec):
        for dim, axis in enumerate(spec):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = int(np.prod([MESH.shape[a] for a in axes]))
            assert leaf.shape[dim] % size == 0, (arch, leaf.shape, spec)

    jax.tree.map(check, cache_shape, specs, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.slow  # full pjit compile of a reduced model (~40s)
def test_pjit_runs_on_host_mesh():
    """End-to-end pjit with the rules engine on the single host device."""
    from repro.launch.mesh import make_host_mesh
    from repro.training.optimizer import AdamW, constant_schedule
    from repro.training.steps import make_train_step
    cfg = get_config("gemma_2b").reduced().with_(objective="ar")
    mesh = make_host_mesh()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    pspec = R.param_specs(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), params), mesh)
    psh = R.to_shardings(pspec, mesh)
    opt = AdamW(constant_schedule(1e-3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt), in_shardings=(psh, None, None, None))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab_size)}
    with mesh:
        p2, o2, m = step(params, opt_state, batch, jax.random.PRNGKey(2))
    assert np.isfinite(float(m["loss"]))
