"""Training substrate: optimizer math, checkpoint round-trip, data pipeline
determinism, loss decrease on real (synthetic-corpus) training."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.data.pipeline import MarkovTextSource, make_batch
from repro.models import transformer as T
from repro.training import checkpoint as CKPT
from repro.training.optimizer import AdamW, constant_schedule, cosine_schedule, global_norm
from repro.training.steps import make_train_step


def test_adamw_converges_quadratic():
    """AdamW drives a quadratic to its minimum."""
    opt = AdamW(constant_schedule(0.1), weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    target = jnp.array([1.0, 2.0])
    state = opt.init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip_bounds_update():
    opt = AdamW(constant_schedule(1.0), clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, m = opt.update(huge, state, params)
    assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    # effective grads clipped to norm 1 -> first Adam step is bounded
    # (bias-corrected first step is +-lr regardless, but must be finite)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-6)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr(55)) < float(lr(11))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_global_norm_matches_numpy(seed):
    rng = np.random.RandomState(seed)
    tree = {"a": rng.randn(3, 4).astype(np.float32),
            "b": [rng.randn(5).astype(np.float32)]}
    got = float(global_norm(jax.tree.map(jnp.asarray, tree)))
    want = np.sqrt(sum((l ** 2).sum() for l in [tree["a"], tree["b"][0]]))
    assert got == pytest.approx(float(want), rel=1e-5)


def test_checkpoint_roundtrip_and_latest():
    cfg = get_config("gemma_2b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(d, 5, params, {"note": "a"})
        CKPT.save(d, 10, params, {"note": "b"})
        assert CKPT.latest_step(d) == 10
        restored, meta = CKPT.restore(d, params)
        assert meta["note"] == "b"
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_missing_key_raises():
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(d, 1, {"a": jnp.zeros(3)})
        with pytest.raises(KeyError):
            CKPT.restore(d, {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_markov_source_deterministic_and_banded():
    src = MarkovTextSource(1024, seed=3)
    a = src.batch(7, 4, 64)
    b = src.batch(7, 4, 64)
    np.testing.assert_array_equal(a, b)
    c = src.batch(8, 4, 64)
    assert not np.array_equal(a, c)
    d = np.abs((a[:, 1:] - a[:, :-1]) % 1024)
    d = np.minimum(d, 1024 - d)
    assert (d < 16).mean() > 0.8  # banded transitions dominate


@pytest.mark.slow  # ~200s of real training across both objectives
@pytest.mark.parametrize("objective", ["ar", "diffusion"])
def test_loss_decreases_on_synthetic_corpus(objective):
    """30 steps of real training on the Markov corpus must reduce the loss --
    end-to-end: data pipeline -> model -> loss -> optimizer."""
    cfg = get_config("cifar10_scorenet").with_(objective=objective,
                                               vocab_size=256)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(constant_schedule(3e-4))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    src = MarkovTextSource(cfg.vocab_size, seed=0)
    rng = jax.random.PRNGKey(1)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, src, i, 16, 32).items()}
        rng, sub = jax.random.split(rng)
        params, opt_state, m = step(params, opt_state, batch, sub)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses
